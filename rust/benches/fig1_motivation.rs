//! Figure 1 — chunked-prefill motivation study.
//!
//! (a) linear-layer saturation: achieved TFLOP/s of a 4096x4096 linear vs
//!     token count on A100 and H100; the knee moves ~2K -> ~8K tokens.
//! (b) prefill-only iteration latency under the 8192-token budget, with
//!     the attention share of forward latency (grows to ~25% at 8192x1).
//! (c) decode-only latency at a fixed budget of 8 as context grows
//!     (>4x inflation from KV reads).
//!
//!     cargo bench --bench fig1_motivation

use duetserve::config::{GpuSpec, ModelSpec};
use duetserve::model::ops::{linear_bytes, linear_flops};
use duetserve::model::AttnShape;
use duetserve::roofline::{BatchShape, Predictor};
use duetserve::sim::{DispatchMode, GpuExecutor};
use duetserve::util::tablefmt::{banner, Table};

/// Achieved linear-layer throughput on the simulated device: roofline
/// with the GEMM-saturation curve (tile/wave quantization at small token
/// counts — `GpuSpec::gemm_eff`) on top of the 0.8/0.85 asymptotic
/// compute/bandwidth efficiencies the executor uses.
fn linear_tflops(gpu: &GpuSpec, tokens: u64) -> f64 {
    let f = linear_flops(tokens, 4096, 4096);
    let b = linear_bytes(tokens, 4096, 4096, 2);
    let pi = gpu.peak_flops * 0.80 * gpu.gemm_eff(tokens);
    let t = (f as f64 / pi).max(b as f64 / (gpu.hbm_bandwidth * 0.85));
    f as f64 / t / 1e12
}

fn fig1a() {
    banner("Fig 1(a): 4096x4096 linear saturation vs token count");
    let gpus = [GpuSpec::a100(), GpuSpec::h100()];
    let mut t = Table::new(vec!["tokens", "A100 TFLOP/s", "H100 TFLOP/s"]);
    let tokens: Vec<u64> = (8..=15).map(|p| 1u64 << p).collect(); // 256..32768
    for &n in &tokens {
        t.row(vec![
            format!("{n}"),
            format!("{:.0}", linear_tflops(&gpus[0], n)),
            format!("{:.0}", linear_tflops(&gpus[1], n)),
        ]);
    }
    t.print();
    for gpu in &gpus {
        let peak = linear_tflops(gpu, 1 << 20);
        let knee = tokens
            .iter()
            .find(|&&n| linear_tflops(gpu, n) >= 0.95 * peak)
            .copied()
            .unwrap_or(0);
        println!(
            "{}: saturates near {} tokens (paper: {})",
            gpu.name,
            knee,
            if gpu.name == "A100" { "~2K" } else { "~8K" }
        );
    }
}

fn fig1b() {
    banner("Fig 1(b): prefill-only latency under an 8192-token budget (Qwen3-8B, H100)");
    let spec = ModelSpec::qwen3_8b();
    let gpu = GpuSpec::h100();
    let mut exec = GpuExecutor::noiseless(spec.clone(), gpu.clone(), 1);
    let pred = Predictor::new(spec, gpu, 1);
    let mut t = Table::new(vec![
        "batch",
        "latency(ms)",
        "attention-share",
        "100ms-TBT-SLO",
    ]);
    for &(n_req, len) in &[(8u64, 1024u64), (4, 2048), (2, 4096), (1, 8192)] {
        let shapes: Vec<AttnShape> = (0..n_req).map(|_| AttnShape { q: len, c: 0 }).collect();
        let batch = BatchShape::from_shapes(shapes);
        let res = exec.run(&batch, 132, DispatchMode::Eager, None);
        let br = pred.predict(&batch, 132);
        let share = br.attention / br.total();
        t.row(vec![
            format!("{n_req}x{len}"),
            format!("{:.1}", res.total() * 1e3),
            format!("{:.0}%", share * 100.0),
            if res.total() > 0.100 { "VIOLATED" } else { "ok" }.to_string(),
        ]);
    }
    t.print();
    println!("(paper: all >180 ms; attention ~25% of forward at 1x8192)");
}

fn fig1c() {
    banner("Fig 1(c): decode-only latency, budget 8, growing context (Qwen3-8B, H100)");
    let mut exec = GpuExecutor::noiseless(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1);
    let mut t = Table::new(vec!["context", "latency(ms)", "vs 1K"]);
    let base = {
        let b = BatchShape::from_shapes((0..8).map(|_| AttnShape { q: 1, c: 1024 }).collect());
        exec.run(&b, 132, DispatchMode::Graph, None).gpu_time
    };
    for &ctx in &[1024u64, 2048, 4096, 8192, 16384, 32768] {
        let b = BatchShape::from_shapes((0..8).map(|_| AttnShape { q: 1, c: ctx }).collect());
        let lat = exec.run(&b, 132, DispatchMode::Graph, None).gpu_time;
        t.row(vec![
            format!("{ctx}"),
            format!("{:.2}", lat * 1e3),
            format!("{:.1}x", lat / base),
        ]);
    }
    t.print();
    println!("(paper: >4x spread — KV reads dominate decode at long context)");
}

fn main() {
    fig1a();
    fig1b();
    fig1c();
}
