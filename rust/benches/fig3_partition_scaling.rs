//! Figure 3 — the opportunity behind SM multiplexing.
//!
//! (a) HBM bandwidth and FLOPs vs active TPC count: bandwidth scales
//!     super-linearly (20% of SMs ≈ 60% of peak BW), FLOPs linearly.
//! (b,c) prefill saturates SMs but leaves HBM idle; decode is the
//!     opposite — the complementarity DuetServe exploits.
//!
//!     cargo bench --bench fig3_partition_scaling

use duetserve::config::{GpuSpec, ModelSpec};
use duetserve::model::AttnShape;
use duetserve::roofline::BatchShape;
use duetserve::sim::{DispatchMode, GpuExecutor};
use duetserve::util::tablefmt::{banner, Table};

fn fig3a() {
    banner("Fig 3(a): achieved HBM bandwidth and FLOPs vs active TPCs (H100)");
    let gpu = GpuSpec::h100();
    let mut t = Table::new(vec![
        "tpcs",
        "frac",
        "bw(GB/s)",
        "bw-frac",
        "tflops",
        "flops-frac",
    ]);
    for tpcs in [4u32, 7, 13, 20, 26, 33, 40, 46, 53, 59, 66] {
        let sms = tpcs * gpu.sms_per_tpc;
        let bw = gpu.b_hbm(sms);
        let pi = gpu.pi_sm(sms);
        t.row(vec![
            format!("{tpcs}"),
            format!("{:.2}", tpcs as f64 / 66.0),
            format!("{:.0}", bw / 1e9),
            format!("{:.2}", bw / gpu.hbm_bandwidth),
            format!("{:.0}", pi / 1e12),
            format!("{:.2}", pi / gpu.peak_flops),
        ]);
    }
    t.print();
    let sms20 = (0.2 * gpu.num_sms as f64) as u32;
    println!(
        "20% of SMs -> {:.0}% of peak bandwidth (paper: ~60%)",
        gpu.b_hbm(sms20) / gpu.hbm_bandwidth * 100.0
    );
}

fn fig3bc() {
    banner("Fig 3(b,c): phase resource utilization (Qwen3-8B, full device)");
    let mut exec = GpuExecutor::noiseless(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1);
    let prefill = BatchShape::from_shapes(vec![AttnShape { q: 8192, c: 0 }]);
    let decode =
        BatchShape::from_shapes((0..64).map(|_| AttnShape { q: 1, c: 8192 }).collect());
    let rp = exec.run(&prefill, 132, DispatchMode::Eager, None);
    let rd = exec.run(&decode, 132, DispatchMode::Graph, None);
    let mut t = Table::new(vec!["phase", "sm-util", "hbm-util"]);
    t.row(vec![
        "prefill (8192 tok)".to_string(),
        format!("{:.2}", rp.sm_util),
        format!("{:.2}", rp.hbm_util),
    ]);
    t.row(vec![
        "decode (64 x 8K ctx)".to_string(),
        format!("{:.2}", rd.sm_util),
        format!("{:.2}", rd.hbm_util),
    ]);
    t.print();
    println!(
        "(paper: prefill = compute-bound/HBM-idle, decode = HBM-bound/SM-idle\n\
         -> complementary demands enable spatial co-execution)"
    );
}

fn main() {
    fig3a();
    fig3bc();
}
