//! Figure 2 — PD aggregated (2 replicas, round-robin) vs PD disaggregated
//! (1P+1D) on two H100s, Qwen3-8B, 8000-in/200-out requests, QPS sweep.
//!
//! Paper shape to reproduce: disagg TBT stays flat but TTFT blows up past
//! QPS≈4 and total token throughput is less than half of aggregated;
//! aggregated saturates around QPS≈7.
//!
//!     cargo bench --bench fig2_agg_vs_disagg

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{DisaggEngine, LeastOutstandingRouter, ReplicatedEngine};
use duetserve::util::tablefmt::{banner, Table};
use duetserve::workload::synthetic::{fixed_workload, jittered_workload};

fn main() {
    banner("Fig 2: Agg-vLLM (2 replicas) vs Disagg-Dynamo (1P+1D), 8000in/200out");
    let base = ServingConfig::default_8b();
    let n = 120;
    let mut t = Table::new(vec![
        "qps",
        "agg-ttft(s)",
        "dis-ttft(s)",
        "agg-tbt(ms)",
        "dis-tbt(ms)",
        "agg-tok/s",
        "dis-tok/s",
    ]);
    for &qps in &[1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0] {
        let w = fixed_workload(n, 8000, 200, qps, 0xF16_2);

        let mut agg = ReplicatedEngine::new(
            base.clone().with_policy(Policy::VllmChunked),
            2,
            1,
        );
        let ra = agg.run(w.clone());

        let mut dis = DisaggEngine::new(
            base.clone().with_policy(Policy::DisaggPD {
                prefill_gpus: 1,
                decode_gpus: 1,
            }),
            1,
            1,
            1,
        );
        let rd = dis.run(w);

        t.row(vec![
            format!("{qps:.0}"),
            format!("{:.2}", ra.ttft.mean),
            format!("{:.2}", rd.ttft.mean),
            format!("{:.1}", ra.tbt.mean * 1e3),
            format!("{:.1}", rd.tbt.mean * 1e3),
            format!("{:.0}", ra.token_throughput),
            format!("{:.0}", rd.token_throughput),
        ]);
    }
    t.print();
    println!(
        "\n(paper: disagg TTFT rises sharply past QPS 4; agg saturates ~QPS 7;\n\
         disagg total tokens/s < 1/2 of agg — the single prefill GPU is the\n\
         bottleneck while both agg GPUs prefill concurrently)"
    );

    router_comparison();
}

/// Routing-seam addendum: the 2-replica aggregated front-end under
/// round-robin vs least-outstanding-token dispatch on a length-skewed
/// workload (jittered prompts make static alternation imbalanced).
fn router_comparison() {
    banner("Fig 2 addendum: 2-replica agg, round-robin vs least-loaded routing");
    let base = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    let n = 120;
    let mut t = Table::new(vec![
        "qps",
        "rr-ttft(s)",
        "ll-ttft(s)",
        "rr-p99tbt(ms)",
        "ll-p99tbt(ms)",
        "rr-tok/s",
        "ll-tok/s",
    ]);
    for &qps in &[2.0f64, 4.0, 6.0, 8.0] {
        let w = jittered_workload(n, 8000, 200, 0.8, qps, 0xF16_2);

        let mut rr = ReplicatedEngine::new(base.clone(), 2, 1);
        let r_rr = rr.run(w.clone());

        let mut ll = ReplicatedEngine::new(base.clone(), 2, 1)
            .with_router(Box::new(LeastOutstandingRouter::new()));
        let r_ll = ll.run(w);

        t.row(vec![
            format!("{qps:.0}"),
            format!("{:.2}", r_rr.ttft.mean),
            format!("{:.2}", r_ll.ttft.mean),
            format!("{:.1}", r_rr.tbt_p99 * 1e3),
            format!("{:.1}", r_ll.tbt_p99 * 1e3),
            format!("{:.0}", r_rr.token_throughput),
            format!("{:.0}", r_ll.token_throughput),
        ]);
    }
    t.print();
    println!(
        "\n(per-arrival load-aware dispatch absorbs length skew that static\n\
         round-robin piles onto one replica; the gap widens with qps)"
    );
}
