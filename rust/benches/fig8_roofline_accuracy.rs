//! Figure 8 (Appendix A) — roofline predictor accuracy: predicted vs
//! "profiled" (simulated-hardware) latency across TPC counts, for the
//! 8x1024 prefill and 16x1024 decode workloads on Qwen3-8B (TP=1) and
//! Qwen3-14B (TP=2).
//!
//! Paper shape: prefill tracks closely (near-linear until ~40 TPCs, then
//! flattens); decode is intentionally conservative — the model
//! OVER-estimates decode latency at small TPC counts.
//!
//!     cargo bench --bench fig8_roofline_accuracy

use duetserve::config::{GpuSpec, ModelSpec};
use duetserve::model::AttnShape;
use duetserve::roofline::{BatchShape, Predictor};
use duetserve::sim::{DispatchMode, GpuExecutor};
use duetserve::util::stats::mape;
use duetserve::util::tablefmt::{banner, Table};

fn study(model: ModelSpec, tp: u32) {
    banner(&format!("Fig 8: {} (TP={tp})", model.name));
    let gpu = GpuSpec::h100();
    let pred = Predictor::new(model.clone(), gpu.clone(), tp);
    let mut exec = GpuExecutor::noiseless(model, gpu.clone(), tp);

    let prefill = BatchShape::from_shapes((0..8).map(|_| AttnShape { q: 1024, c: 0 }).collect());
    let decode =
        BatchShape::from_shapes((0..16).map(|_| AttnShape { q: 1, c: 1024 }).collect());

    let mut t = Table::new(vec![
        "tpcs",
        "pre-pred(ms)",
        "pre-meas(ms)",
        "dec-pred(ms)",
        "dec-meas(ms)",
        "dec pred/meas",
    ]);
    let mut pre_pred = Vec::new();
    let mut pre_meas = Vec::new();
    let mut small_tpc_conservative = true;
    for tpcs in [4u32, 8, 12, 18, 24, 33, 40, 50, 60, 66] {
        let sms = tpcs * gpu.sms_per_tpc;
        let pp = pred.predict_total(&prefill, sms);
        let pm = exec.run(&prefill, sms, DispatchMode::Eager, None).gpu_time;
        let dp = pred.predict_total(&decode, sms);
        let dm = exec.run(&decode, sms, DispatchMode::Graph, None).gpu_time;
        pre_pred.push(pp);
        pre_meas.push(pm);
        if tpcs <= 8 && dp < dm {
            small_tpc_conservative = false;
        }
        t.row(vec![
            format!("{tpcs}"),
            format!("{:.1}", pp * 1e3),
            format!("{:.1}", pm * 1e3),
            format!("{:.2}", dp * 1e3),
            format!("{:.2}", dm * 1e3),
            format!("{:.2}", dp / dm),
        ]);
    }
    t.print();
    println!(
        "prefill MAPE {:.1}% (prediction is an idealized lower bound; the\n\
         profiled curve includes kernel efficiencies); decode conservative at\n\
         small TPC counts: {}",
        mape(&pre_pred, &pre_meas),
        if small_tpc_conservative { "yes (pred > measured, as in the paper)" } else { "NO" }
    );
}

fn main() {
    study(ModelSpec::qwen3_8b(), 1);
    study(ModelSpec::qwen3_14b(), 2);
}
