//! Table 2 (Appendix A) — workload sensitivity: fixed ISL 4096, varying
//! OSL ∈ {64, 1024, 2048}, vLLM vs DuetServe at max serving capacity.
//!
//! Paper shape: prefill-heavy (short OSL) shows the largest gain
//! (1.28x throughput, TBT 170→105 ms); decode-heavy approaches parity
//! (1.04x) because DuetServe stays in aggregated mode when there is
//! little prefill-decode contention.
//!
//!     cargo bench --bench table2_workload_sensitivity

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::engine_for;
use duetserve::util::tablefmt::{banner, Table};
use duetserve::workload::synthetic::fixed_workload;

fn main() {
    banner("Table 2: ISL 4096, OSL sweep — vLLM vs DuetServe at saturation");
    let base = ServingConfig::default_8b();
    let quick = std::env::var("DUET_BENCH_QUICK").is_ok();
    let mut t = Table::new(vec![
        "isl",
        "osl",
        "isl/osl",
        "vllm req/s",
        "duet req/s",
        "vllm tbt(ms)",
        "duet tbt(ms)",
        "gain",
        "spatial-iters",
    ]);
    // Saturating arrival rates per OSL (beyond capacity so throughput is
    // engine-limited, like the paper's "maximum serving capacity").
    for &(osl, qps, n) in &[
        (64u64, 20.0f64, if quick { 120 } else { 240 }),
        (1024, 12.0, if quick { 80 } else { 160 }),
        (2048, 9.0, if quick { 60 } else { 120 }),
    ] {
        let w = fixed_workload(n, 4096, osl, qps, 0x7AB2);
        let mut ev = engine_for(base.clone().with_policy(Policy::VllmChunked), 1);
        let rv = ev.run(w.clone());
        let mut ed = engine_for(base.clone().with_policy(Policy::Duet), 1);
        let rd = ed.run(w);
        t.row(vec![
            "4096".to_string(),
            format!("{osl}"),
            format!("{:.0}", 4096.0 / osl as f64),
            format!("{:.2}", rv.throughput_rps),
            format!("{:.2}", rd.throughput_rps),
            format!("{:.0}", rv.tbt.mean * 1e3),
            format!("{:.0}", rd.tbt.mean * 1e3),
            format!("{:.2}x", rd.throughput_rps / rv.throughput_rps),
            format!(
                "{}/{}",
                rd.spatial_iterations, rd.iterations
            ),
        ]);
    }
    t.print();
    println!(
        "\n(paper: 1.28x at OSL 64, 1.11x at 1024, 1.04x at 2048 — gains\n\
         shrink as the workload turns decode-dominant and DuetServe stays\n\
         aggregated)"
    );
}
