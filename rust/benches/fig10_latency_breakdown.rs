//! Figure 10 (Appendix A) — iteration timeline: CPU scheduling overhead,
//! spatial iterations (Sd/Sp TPC split, k look-ahead steps) interleaved
//! with aggregated iterations as load fluctuates.
//!
//! Paper shape: a spatial iteration (e.g. 48 prefill / 18 decode TPCs,
//! k=5 decode steps) followed by a return to aggregated mode; CPU
//! scheduling (incl. the Algorithm-1 solve) under 1 ms.
//!
//!     cargo bench --bench fig10_latency_breakdown

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{engine_for, IterKind};
use duetserve::util::tablefmt::banner;
use duetserve::workload::synthetic::fixed_workload;

fn main() {
    banner("Fig 10: DuetServe iteration timeline (Qwen3-8B, H100)");
    let mut e = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 3);
    e.log_events = true;
    // Bursty prefill-heavy load so the engine alternates between spatial
    // and aggregated iterations.
    let w = fixed_workload(40, 8000, 96, 6.0, 4);
    let rep = e.run(w);

    // Print a window around the first spatial→aggregated transition.
    let first_spatial = e
        .events
        .iter()
        .position(|ev| matches!(ev.kind, IterKind::Spatial { .. }))
        .unwrap_or(0);
    let lo = first_spatial.saturating_sub(2);
    let hi = (first_spatial + 12).min(e.events.len());
    for ev in &e.events[lo..hi] {
        println!("{}", ev.describe());
    }

    let max_sched = e
        .events
        .iter()
        .map(|ev| ev.sched_s)
        .fold(0.0f64, f64::max);
    let spatial = e
        .events
        .iter()
        .filter(|ev| matches!(ev.kind, IterKind::Spatial { .. }))
        .count();
    println!(
        "\niterations: {} total, {} spatial; max CPU scheduling time \
         {:.3} ms (paper: <1 ms incl. the partition solve)",
        e.events.len(),
        spatial,
        max_sched * 1e3
    );
    println!(
        "completed {} requests, mean TBT {:.1} ms, throughput {:.2} req/s",
        rep.completed,
        rep.tbt.mean * 1e3,
        rep.throughput_rps
    );
    assert!(
        max_sched < 1e-3,
        "scheduling overhead must stay under the paper's 1 ms budget"
    );
}
