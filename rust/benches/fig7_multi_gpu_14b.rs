//! Figure 7 — multi-GPU inference: Azure-Code with Qwen3-14B under TP=2
//! for the aggregated systems, vs Dynamo 1P+1D on the same two GPUs.
//!
//! Paper shape: DuetServe-TP2 second-lowest TBT (Dynamo lowest) but the
//! highest throughput; vLLM/SGLang-Chunked TBT rises past QPS 13;
//! SGLang-Default unbounded; Dynamo's prefill GPU bottlenecks throughput.
//!
//!     cargo bench --bench fig7_multi_gpu_14b

use duetserve::config::{ModelSpec, Policy, ServingConfig};
use duetserve::engine::{engine_for, DisaggEngine};
use duetserve::metrics::Report;
use duetserve::util::tablefmt::{banner, Table};
use duetserve::workload::traces::{generate, TraceKind};

fn main() {
    banner("Fig 7: Azure-Code, Qwen3-14B (TP=2) vs Dynamo-1P1D");
    let base = ServingConfig::default_8b().with_model(ModelSpec::qwen3_14b(), 2);
    let quick = std::env::var("DUET_BENCH_QUICK").is_ok();
    let n = if quick { 120 } else { 300 };
    let mut t = Table::new(Report::header());
    for &qps in &[4.0f64, 8.0, 12.0, 14.0, 16.0] {
        let w = generate(TraceKind::AzureCode, Some(n), qps, 77);
        for policy in [
            Policy::VllmChunked,
            Policy::SglangDefault,
            Policy::SglangChunked,
            Policy::Duet,
        ] {
            let mut e = engine_for(base.clone().with_policy(policy), 1);
            let mut rep = e.run(w.clone());
            rep.system = format!("{}-TP2", rep.system);
            t.row(rep.row(qps));
        }
        // Dynamo: each worker holds a full 14B replica on one GPU (TP=1
        // per worker) — the paper's 1P+1D layout on the 2-GPU testbed.
        let mut dcfg = base.clone().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        });
        dcfg.tp = 1;
        let mut dis = DisaggEngine::new(dcfg, 1, 1, 1);
        t.row(dis.run(w).row(qps));
    }
    t.print();
    println!(
        "\n(paper: Duet-TP2 sustains TBT <150ms at saturation with highest\n\
         throughput; Dynamo lowest TBT but worst throughput — decode GPU\n\
         starved behind the single prefill GPU)"
    );
}
