//! Figure 6 — end-to-end performance on the three workloads, Qwen3-8B
//! (TP=1): mean TTFT, mean TBT, and output request throughput vs QPS for
//! vLLM, SGLang-Default, SGLang-Chunked, Dynamo-1P1D and DuetServe.
//!
//! Paper shape to reproduce: DuetServe has the lowest TBT and highest
//! req/s throughput at saturation (1.1x SGLang-Default on Azure-Code at
//! QPS 16; 1.3x vLLM on Mooncake at QPS 5); SGLang-Default's TBT grows
//! unboundedly; DuetServe trades a little TTFT at light load.
//!
//! Full traces are huge; we replay a fixed-size prefix at each QPS (the
//! shape, not the absolute durations, is the target).
//!
//!     cargo bench --bench fig6_end_to_end_8b

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{DisaggEngine, ReplicatedEngine};
use duetserve::metrics::Report;
use duetserve::util::tablefmt::{banner, Table};
use duetserve::workload::traces::{generate, TraceKind};

fn run_all(trace: TraceKind, n: usize, qps_grid: &[f64]) {
    banner(&format!(
        "Fig 6: {} (Qwen3-8B TP=1; testbed = 2x H100: aggregated systems \
         run 2 round-robin replicas, Dynamo uses the GPUs as 1P+1D)",
        trace.name()
    ));
    let base = ServingConfig::default_8b();
    let mut t = Table::new(Report::header());
    for &qps in qps_grid {
        let w = generate(trace, Some(n), qps, 66);
        for policy in [
            Policy::VllmChunked,
            Policy::SglangDefault,
            Policy::SglangChunked,
            Policy::Duet,
        ] {
            let mut e = ReplicatedEngine::new(base.clone().with_policy(policy), 2, 1);
            t.row(e.run(w.clone()).row(qps));
        }
        let mut dis = DisaggEngine::new(
            base.clone().with_policy(Policy::DisaggPD {
                prefill_gpus: 1,
                decode_gpus: 1,
            }),
            1,
            1,
            1,
        );
        t.row(dis.run(w).row(qps));
    }
    t.print();
}

fn main() {
    let quick = std::env::var("DUET_BENCH_QUICK").is_ok();
    let n = if quick { 120 } else { 300 };
    run_all(TraceKind::AzureCode, n, &[8.0, 16.0, 24.0, 30.0]);
    run_all(TraceKind::AzureConv, n, &[8.0, 15.0, 22.0, 28.0]);
    run_all(TraceKind::Mooncake, n.min(200), &[1.0, 3.0, 5.0]);
    println!(
        "\n(paper: DuetServe = lowest TBT + highest req/s at saturation;\n\
         SGLang-Default TBT unbounded; Duet TTFT slightly higher at light load\n\
         — the intentional decode-priority tradeoff)"
    );
}
