//! Design-choice ablations beyond the paper's own (DESIGN.md):
//!
//! (a) Algorithm 1 *verbatim* vs the realized-gap strengthening this repo
//!     ships (reject configs whose span/k exceeds the TBT SLO even though
//!     each decode step satisfies it).
//! (b) Look-ahead cap sensitivity (max k).
//! (c) Heterogeneous disaggregation (Appendix B future work):
//!     compute-optimized prefill + memory-optimized decode parts vs a
//!     homogeneous H100 pair, and vs DuetServe on one H100.
//!
//!     cargo bench --bench ablation_design

use duetserve::config::{GpuSpec, Policy, ServingConfig};
use duetserve::engine::{engine_for, DisaggEngine, SimEngine};
use duetserve::roofline::Predictor;
use duetserve::sched::DuetScheduler;
use duetserve::util::tablefmt::{banner, Table};
use duetserve::workload::synthetic::fixed_workload;

fn duet_engine(cfg: ServingConfig, verbatim: bool, max_k: u32, seed: u64) -> SimEngine {
    let pred = Predictor::new(cfg.model.clone(), cfg.gpu.clone(), cfg.tp);
    let mut sched = DuetScheduler::new(
        pred,
        cfg.token_budget as u64,
        cfg.max_batch as usize,
        cfg.kv_watermark,
        cfg.tbt_slo,
        max_k,
    );
    sched.verbatim_alg1 = verbatim;
    SimEngine::new(cfg, Box::new(sched), seed)
}

fn ablation_a_and_b() {
    banner("Ablation (a,b): Algorithm-1 variant x look-ahead cap (4096in/64out @ QPS 20)");
    let base = ServingConfig::default_8b();
    let mut t = Table::new(vec![
        "variant",
        "max-k",
        "thpt(req/s)",
        "tbt-mean(ms)",
        "tbt-p99(ms)",
        "spatial",
    ]);
    for &(verbatim, label) in &[(true, "verbatim"), (false, "realized-gap")] {
        for &max_k in &[1u32, 4, 16, 64] {
            let w = fixed_workload(160, 4096, 64, 20.0, 0xAB1A);
            let mut e = duet_engine(base.clone(), verbatim, max_k, 1);
            let rep = e.run(w);
            t.row(vec![
                label.to_string(),
                format!("{max_k}"),
                format!("{:.2}", rep.throughput_rps),
                format!("{:.0}", rep.tbt.mean * 1e3),
                format!("{:.0}", rep.tbt_p99 * 1e3),
                format!("{}/{}", rep.spatial_iterations, rep.iterations),
            ]);
        }
    }
    t.print();
    println!(
        "(the verbatim solver favors tiny decode partitions with k=1 whose\n\
         realized inter-token gap equals the prefill span — the strengthened\n\
         constraint is what holds p99 TBT near the SLO)"
    );
}

fn ablation_c() {
    banner("Ablation (c): heterogeneous PD disaggregation (8000in/200out @ QPS 5)");
    let base = ServingConfig::default_8b();
    let w = fixed_workload(80, 8000, 200, 5.0, 0xC0DE);
    let mut t = Table::new(vec![
        "topology",
        "thpt(req/s)",
        "tok/s",
        "ttft(s)",
        "tbt(ms)",
    ]);

    // Homogeneous 1P+1D on H100s.
    let mut homo = DisaggEngine::new(
        base.clone().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        }),
        1,
        1,
        1,
    );
    let rh = homo.run(w.clone());
    t.row(vec![
        "H100-P + H100-D".to_string(),
        format!("{:.2}", rh.throughput_rps),
        format!("{:.0}", rh.token_throughput),
        format!("{:.2}", rh.ttft.mean),
        format!("{:.1}", rh.tbt.mean * 1e3),
    ]);

    // Heterogeneous: compute-optimized prefill + memory-optimized decode.
    let mut het = DisaggEngine::new_hetero(
        base.clone().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        }),
        1,
        GpuSpec::compute_optimized(),
        1,
        GpuSpec::memory_optimized(),
        1,
    );
    let rx = het.run(w.clone());
    t.row(vec![
        "C-OPT-P + M-OPT-D".to_string(),
        format!("{:.2}", rx.throughput_rps),
        format!("{:.0}", rx.token_throughput),
        format!("{:.2}", rx.ttft.mean),
        format!("{:.1}", rx.tbt.mean * 1e3),
    ]);

    // DuetServe on a single H100 for reference.
    let mut duet = engine_for(base.with_policy(Policy::Duet), 1);
    let rd = duet.run(w);
    t.row(vec![
        "DuetServe (1x H100)".to_string(),
        format!("{:.2}", rd.throughput_rps),
        format!("{:.0}", rd.token_throughput),
        format!("{:.2}", rd.ttft.mean),
        format!("{:.1}", rd.tbt.mean * 1e3),
    ]);
    t.print();
    println!(
        "(phase-matched parts recover most of the homogeneous pair's\n\
         throughput at lower nominal cost; DuetServe reaches comparable\n\
         per-GPU efficiency on a single device — Appendix B's direction)"
    );
}

fn main() {
    ablation_a_and_b();
    ablation_c();
}
