//! Table 1 — workload trace statistics. Regenerates the published
//! #requests / mean-ISL / mean-OSL rows from the calibrated synthetic
//! trace generators (full published request counts).
//!
//!     cargo bench --bench table1_traces

use duetserve::util::tablefmt::{banner, Table};
use duetserve::workload::traces::{generate, TraceKind};

fn main() {
    banner("Table 1: workload traces");
    let mut t = Table::new(vec![
        "trace",
        "#requests",
        "ISL(meas)",
        "OSL(meas)",
        "ISL(paper)",
        "OSL(paper)",
    ]);
    for kind in TraceKind::all() {
        let (n, isl, osl, _, _) = kind.calibration();
        // Sample at the published request count (QPS irrelevant to stats).
        let w = generate(kind, Some(n), 10.0, 1);
        let s = w.stats();
        t.row(vec![
            kind.name().to_string(),
            format!("{}", s.n_requests),
            format!("{:.0}", s.mean_isl),
            format!("{:.0}", s.mean_osl),
            format!("{isl:.0}"),
            format!("{osl:.0}"),
        ]);
    }
    t.print();
}
