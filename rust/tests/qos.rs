//! QoS/SLO-class integration properties.
//!
//! The load-bearing guarantee of the SLO-class redesign: with every
//! request in one class and no SLO pressure (nothing declares a TBT
//! SLO), the QoS-aware scheduler and admission path must be a strict
//! no-op — reports and per-token emission times byte-identical to the
//! pre-QoS (`with_qos(false)`) scheduler, on both the single-GPU engine
//! and a routed 2-worker cluster. Plus: per-class goodput accounting
//! must survive the cluster's cross-worker recorder fold.

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{engine_for, router_by_name, ClusterEngine};
use duetserve::request::{Request, SloClass};
use duetserve::util::proptest::check;
use duetserve::workload::synthetic::jittered_workload;
use duetserve::workload::Workload;

fn duet_cfg(qos: bool) -> ServingConfig {
    ServingConfig::default_8b()
        .with_policy(Policy::Duet)
        .with_qos(qos)
}

/// Every finished request's id and full token-emission timeline, sorted
/// by id so cross-run comparison is order-independent.
fn token_timelines(finished: &[Request]) -> Vec<(u64, Vec<f64>)> {
    let mut t: Vec<(u64, Vec<f64>)> = finished
        .iter()
        .map(|r| (r.id, r.token_times.clone()))
        .collect();
    t.sort_by_key(|(id, _)| *id);
    t
}

/// Single class, no SLO pressure, single-GPU engine: QoS on vs off must
/// be trajectory-identical — same report (field-for-field via Debug) and
/// same per-token emission times.
#[test]
fn qos_is_noop_for_single_class_engine() {
    check(10, |g| {
        let n = g.usize_range(6, 24);
        let isl = g.u64_range(64, 9000);
        let osl = g.u64_range(2, 64);
        let qps = g.f64_range(1.0, 12.0);
        let class = *g.choose(&SloClass::all());
        let mut w = jittered_workload(n, isl, osl, 0.3, qps, g.case_seed);
        w.requests = w.requests.into_iter().map(|r| r.with_class(class)).collect();

        let mut on = engine_for(duet_cfg(true), g.case_seed);
        let rep_on = on.run(w.clone());
        let mut off = engine_for(duet_cfg(false), g.case_seed);
        let rep_off = off.run(w);

        if format!("{rep_on:?}") != format!("{rep_off:?}") {
            return Err(format!(
                "{class:?}: reports diverged:\n  qos-on:  {rep_on:?}\n  qos-off: {rep_off:?}"
            ));
        }
        if rep_on.qos_preemptions != 0 {
            return Err(format!(
                "{class:?}: {} qos preemptions without SLO pressure",
                rep_on.qos_preemptions
            ));
        }
        if token_timelines(&on.finished) != token_timelines(&off.finished) {
            return Err(format!("{class:?}: token emission times diverged"));
        }
        Ok(())
    });
}

/// The same no-op property across a routed 2-worker cluster: the QoS
/// class sort at dispatch is stable, so a single-class cohort keeps its
/// arrival order and the whole trajectory is unchanged.
#[test]
fn qos_is_noop_for_single_class_cluster() {
    check(6, |g| {
        let n = g.usize_range(8, 24);
        let isl = g.u64_range(64, 6000);
        let osl = g.u64_range(2, 48);
        let qps = g.f64_range(1.0, 10.0);
        let class = *g.choose(&SloClass::all());
        let routers = ["round-robin", "least-outstanding"];
        let router = *g.choose(&routers);
        let mut w = jittered_workload(n, isl, osl, 0.3, qps, g.case_seed);
        w.requests = w.requests.into_iter().map(|r| r.with_class(class)).collect();

        let mut on = ClusterEngine::replicated(
            duet_cfg(true),
            2,
            g.case_seed,
            router_by_name(router).expect("known router"),
        );
        let rep_on = on.run(w.clone());
        let mut off = ClusterEngine::replicated(
            duet_cfg(false),
            2,
            g.case_seed,
            router_by_name(router).expect("known router"),
        );
        let rep_off = off.run(w);

        let label = format!("{class:?}/{router}");
        if format!("{rep_on:?}") != format!("{rep_off:?}") {
            return Err(format!(
                "{label}: cluster reports diverged:\n  qos-on:  {rep_on:?}\n  qos-off: {rep_off:?}"
            ));
        }
        if token_timelines(&on.finished) != token_timelines(&off.finished) {
            return Err(format!("{label}: cluster token emission times diverged"));
        }
        Ok(())
    });
}

/// Mixed-class workload over a 2-worker cluster: the per-class goodput
/// slices must survive the cross-worker recorder fold — counts sum to
/// the per-class totals regardless of which worker served each request.
#[test]
fn per_class_attainment_survives_cluster_fold() {
    let mut requests = Vec::new();
    for i in 0..18u64 {
        let class = SloClass::all()[(i % 3) as usize];
        let mut r = Request::new(i, i as f64 * 0.12, 512 + 64 * (i % 4), 8).with_class(class);
        if class == SloClass::Latency {
            // A loose declared SLO: attained, and checked per class.
            r = r.with_slo_tbt(10.0);
        }
        requests.push(r);
    }
    let w = Workload {
        name: "mixed-classes".into(),
        requests,
    }
    .sorted_by_arrival();

    let mut e = ClusterEngine::replicated(
        duet_cfg(true),
        2,
        7,
        router_by_name("round-robin").expect("known router"),
    );
    let rep = e.run(w);

    assert_eq!(rep.completed, 18);
    for class in SloClass::all() {
        let c = rep.class(class);
        assert_eq!(c.completed, 6, "{class:?} count lost in the cluster fold");
        assert!(c.attained <= c.completed);
    }
    // The latency class declared a 10 s TBT SLO nothing violates: fully
    // attained. The SLO-free classes degrade to throughput (attained ==
    // completed by definition).
    assert_eq!(rep.class(SloClass::Latency).attainment(), Some(1.0));
    assert_eq!(rep.class(SloClass::Batch).attainment(), Some(1.0));
}
