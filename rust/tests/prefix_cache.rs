//! Prefix-cache subsystem properties.
//!
//! The load-bearing guarantee is *zero-overlap equivalence*: with the
//! prefix cache enabled but no shared content anywhere in the workload,
//! the engine must be behaviorally indistinguishable from the cache-off
//! path — identical reports and identical per-request token streams —
//! because every capacity signal (`free_blocks`, watermarks, admission)
//! counts cached-unreferenced blocks as free. On top of that: shared
//! prompts must actually hit, and eviction under KV pressure must never
//! corrupt accounting.

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{engine_for, router_by_name, ReplicatedEngine};
use duetserve::metrics::Report;
use duetserve::request::Request;
use duetserve::util::proptest::check;
use duetserve::workload::sessions::{session_workload, shared_prefix_workload, SessionProfile};
use duetserve::workload::Workload;

fn policies() -> Vec<Policy> {
    vec![Policy::VllmChunked, Policy::SglangDefault, Policy::Duet]
}

/// Compare the observable outcome of two runs: merged report metrics and
/// the exact token-time streams of every finished request.
fn assert_equivalent(
    label: &str,
    rep_off: &Report,
    rep_on: &Report,
    fin_off: &[Request],
    fin_on: &[Request],
) -> Result<(), String> {
    if rep_on.prefix_hits != 0 || rep_on.prefix_cached_tokens != 0 {
        return Err(format!(
            "{label}: disjoint prompts must not hit: {} hits, {} tokens",
            rep_on.prefix_hits, rep_on.prefix_cached_tokens
        ));
    }
    if rep_on.completed != rep_off.completed
        || rep_on.iterations != rep_off.iterations
        || rep_on.prefilled_tokens != rep_off.prefilled_tokens
    {
        return Err(format!(
            "{label}: counters diverged: completed {}/{}, iterations {}/{}, prefilled {}/{}",
            rep_on.completed,
            rep_off.completed,
            rep_on.iterations,
            rep_off.iterations,
            rep_on.prefilled_tokens,
            rep_off.prefilled_tokens
        ));
    }
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()));
    if !close(rep_on.duration, rep_off.duration)
        || !close(rep_on.ttft.mean, rep_off.ttft.mean)
        || !close(rep_on.tbt.mean, rep_off.tbt.mean)
    {
        return Err(format!(
            "{label}: timing diverged: duration {}/{} ttft {}/{} tbt {}/{}",
            rep_on.duration,
            rep_off.duration,
            rep_on.ttft.mean,
            rep_off.ttft.mean,
            rep_on.tbt.mean,
            rep_off.tbt.mean
        ));
    }
    let mut off: Vec<&Request> = fin_off.iter().collect();
    let mut on: Vec<&Request> = fin_on.iter().collect();
    off.sort_by_key(|r| r.id);
    on.sort_by_key(|r| r.id);
    if off.len() != on.len() {
        return Err(format!(
            "{label}: finished sets differ: {} vs {}",
            on.len(),
            off.len()
        ));
    }
    for (a, b) in off.iter().zip(on.iter()) {
        if a.id != b.id {
            return Err(format!("{label}: finished ids differ: {} vs {}", a.id, b.id));
        }
        if a.token_times != b.token_times {
            return Err(format!(
                "{label}: request {} token stream diverged (len {} vs {})",
                a.id,
                a.token_times.len(),
                b.token_times.len()
            ));
        }
    }
    Ok(())
}

#[test]
fn zero_overlap_prefix_cache_is_metric_identical_on_the_engine() {
    let pols = policies();
    check(16, |g| {
        let n = g.usize_range(6, 24);
        let unique = g.u64_range(48, 4000);
        let osl = g.u64_range(1, 48);
        let qps = g.f64_range(0.5, 12.0);
        let policy = g.choose(&pols).clone();
        // shared_tokens = 0: every prompt is a fully disjoint token stream.
        let w = shared_prefix_workload(n, 0, unique, osl, qps, 2, g.case_seed);
        let label = format!("{policy:?}/n={n}/isl={unique}");

        let cfg = ServingConfig::default_8b().with_policy(policy);
        let mut off = engine_for(cfg.clone().with_prefix_cache(false), g.case_seed);
        let rep_off = off.run(w.clone());
        let mut on = engine_for(cfg.with_prefix_cache(true), g.case_seed);
        let rep_on = on.run(w);

        on.check_invariants().map_err(|m| format!("{label}: {m}"))?;
        assert_equivalent(&label, &rep_off, &rep_on, &off.finished, &on.finished)
    });
}

#[test]
fn zero_overlap_prefix_cache_is_metric_identical_on_a_cluster() {
    check(10, |g| {
        let n = g.usize_range(6, 20);
        let unique = g.u64_range(48, 3000);
        let osl = g.u64_range(1, 32);
        let qps = g.f64_range(1.0, 10.0);
        let routers = ["round-robin", "least-outstanding", "kv-overlap"];
        let router = *g.choose(&routers);
        let w = shared_prefix_workload(n, 0, unique, osl, qps, 2, g.case_seed);
        let label = format!("2x/{router}/n={n}");

        let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let run = |prefix: bool, w: Workload| {
            let mut e = ReplicatedEngine::new(cfg.clone().with_prefix_cache(prefix), 2, g.case_seed)
                .with_router(router_by_name(router).expect("known router"));
            let rep = e.run(w);
            e.check_invariants().map(|()| (rep, e.finished.clone()))
        };
        let (rep_off, fin_off) = run(false, w.clone()).map_err(|m| format!("{label}: {m}"))?;
        let (rep_on, fin_on) = run(true, w).map_err(|m| format!("{label}: {m}"))?;
        assert_equivalent(&label, &rep_off, &rep_on, &fin_off, &fin_on)
    });
}

#[test]
fn shared_system_prompts_hit_and_cut_prefill_work() {
    // Sequential same-tenant requests (low qps → each finishes before the
    // next arrives): every request after the first per tenant must be
    // seeded from the cache, and the computed prefill volume must drop by
    // exactly the cached-token count.
    let tenants = 2;
    let n = 10;
    let w = shared_prefix_workload(n, 1024, 64, 4, 0.2, tenants, 17);
    let total_prompt: u64 = w.requests.iter().map(|r| r.prompt_len).sum();

    let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    let mut off = engine_for(cfg.clone().with_prefix_cache(false), 1);
    let rep_off = off.run(w.clone());
    let mut on = engine_for(cfg.with_prefix_cache(true), 1);
    let rep_on = on.run(w);
    on.check_invariants().unwrap();

    assert_eq!(rep_off.prefilled_tokens, total_prompt);
    assert!(
        rep_on.prefix_hits >= (n - tenants) as u64,
        "every warm request must hit: {} hits",
        rep_on.prefix_hits
    );
    // At this load nothing is preempted, so prefill work + cached tokens
    // partition the prompt volume exactly.
    assert_eq!(
        rep_on.prefilled_tokens + rep_on.prefix_cached_tokens,
        total_prompt
    );
    // 1024 of 1088 prompt tokens are shareable: at least half the total
    // prefill must have been served from cache.
    assert!(
        rep_on.prefix_cached_tokens * 2 >= total_prompt,
        "cached {} of {total_prompt}",
        rep_on.prefix_cached_tokens
    );
    assert_eq!(rep_on.completed, rep_off.completed);
}

#[test]
fn multi_turn_sessions_reuse_their_own_history() {
    // Turn k's prompt extends turn k-1's, so with think times long enough
    // for turns to finish, later turns hit their session's decayed blocks.
    let p = SessionProfile {
        sessions: 4,
        turns: 3,
        system_tokens: 256,
        user_tokens: 64,
        output_tokens: 8,
        tenants: 2,
        session_qps: 1.0,
        mean_think_s: 4.0,
    };
    let w = session_workload(&p, 23);
    let n = w.requests.len() as u64;
    let cfg = ServingConfig::default_8b()
        .with_policy(Policy::VllmChunked)
        .with_prefix_cache(true);
    let mut e = engine_for(cfg, 2);
    let rep = e.run(w);
    e.check_invariants().unwrap();
    assert_eq!(rep.completed + e.dropped, n);
    assert!(
        rep.prefix_hits > 0 && rep.prefix_cached_tokens > 0,
        "session turns must reuse history: {} hits, {} tokens",
        rep.prefix_hits,
        rep.prefix_cached_tokens
    );
}

#[test]
fn eviction_under_kv_pressure_preserves_invariants() {
    // Tiny KV + shared prompts: finished requests decay blocks into the
    // cached pool, and new allocations must evict them (never failing
    // while cached blocks exist). The engine must survive — via LRU
    // eviction and, past that, recompute preemption — with accounting
    // intact.
    let mut cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    cfg.gpu_mem_util = 0.22;
    cfg = cfg.with_prefix_cache(true);
    let kv_tokens = cfg.kv_capacity_tokens();
    assert!(kv_tokens > 2000, "test needs some KV: {kv_tokens}");
    let mut e = engine_for(cfg, 5);
    // Prompts ~kv/3 each, mostly-disjoint content (shared system prefix of
    // 256 tokens): decayed blocks pile up fast and must be reclaimed.
    let w = shared_prefix_workload(12, 256, kv_tokens / 3, 96, 50.0, 2, 5);
    let rep = e.run(w);
    assert_eq!(rep.completed + e.dropped, 12);
    assert!(rep.completed >= 10, "most requests should finish");
    assert!(
        rep.prefix_evictions > 0,
        "pressure must reclaim cached blocks: {} evictions",
        rep.prefix_evictions
    );
    e.check_invariants().unwrap();
}
