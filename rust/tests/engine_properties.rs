//! Property-based integration tests over the coordinator: randomized
//! workloads and policies must preserve the engine invariants (KV
//! accounting, request lifecycle, token-time monotonicity, conservation
//! of requests) and the cross-policy semantic guarantees.

use std::collections::VecDeque;

use duetserve::config::{ModelSpec, Policy, ServingConfig};
use duetserve::engine::{
    engine_for, router_by_name, ClusterEngine, DisaggEngine, ReplicatedEngine, TopologyStep,
};
use duetserve::request::Request;
use duetserve::util::proptest::check;
use duetserve::workload::synthetic::jittered_workload;
use duetserve::workload::Workload;

fn policies() -> Vec<Policy> {
    vec![
        Policy::VllmChunked,
        Policy::SglangDefault,
        Policy::SglangChunked,
        Policy::Duet,
        Policy::StaticPartition {
            decode_tpcs: 22,
            prefill_tpcs: 44,
        },
    ]
}

#[test]
fn random_workloads_conserve_requests_and_invariants() {
    let pols = policies();
    check(24, |g| {
        let n = g.usize_range(5, 40);
        let isl = g.u64_range(16, 12_000);
        let osl = g.u64_range(1, 128);
        let qps = g.f64_range(0.5, 20.0);
        let policy = g.choose(&pols).clone();
        let w = jittered_workload(n, isl, osl, 0.3, qps, g.case_seed);
        let total_out: u64 = w.requests.iter().map(|r| r.output_len).sum();

        let cfg = ServingConfig::default_8b().with_policy(policy.clone());
        let mut e = engine_for(cfg, g.case_seed);
        let rep = e.run(w);

        e.check_invariants().map_err(|m| format!("{policy:?}: {m}"))?;
        if rep.completed + e.dropped < n as u64 {
            return Err(format!(
                "{policy:?}: lost requests: completed {} + dropped {} < {n}",
                rep.completed, e.dropped
            ));
        }
        if e.dropped == 0 && e.metrics.output_tokens != total_out {
            return Err(format!(
                "{policy:?}: token conservation: {} != {total_out}",
                e.metrics.output_tokens
            ));
        }
        // Iteration-level sanity.
        if rep.completed > 0 && rep.duration <= 0.0 {
            return Err("zero duration with completions".into());
        }
        Ok(())
    });
}

#[test]
fn duet_never_violates_worse_than_vllm_on_p99_tbt() {
    // The paper's core safety claim, as a property over random saturating
    // workloads: Duet's p99 TBT should not exceed vLLM's by more than
    // noise (10%) and usually improves it.
    check(8, |g| {
        let n = g.usize_range(20, 40);
        let isl = g.u64_range(4000, 10_000);
        let osl = g.u64_range(32, 128);
        let qps = g.f64_range(4.0, 12.0);
        let w = jittered_workload(n, isl, osl, 0.2, qps, g.case_seed);

        let mut ev = engine_for(
            ServingConfig::default_8b().with_policy(Policy::VllmChunked),
            1,
        );
        let rv = ev.run(w.clone());
        let mut ed = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 1);
        let rd = ed.run(w);
        if rd.tbt_p99 > rv.tbt_p99 * 1.10 + 1e-3 {
            return Err(format!(
                "duet p99 tbt {:.1}ms worse than vllm {:.1}ms (isl={isl} osl={osl} qps={qps:.1})",
                rd.tbt_p99 * 1e3,
                rv.tbt_p99 * 1e3
            ));
        }
        Ok(())
    });
}

#[test]
fn disagg_conserves_requests_across_random_topologies() {
    // Conservation + causality over random P/D topologies, with the
    // Dynamo-style reconfiguration planner randomly enabled so routing
    // must cope with workers going offline mid-run (the cluster panics if
    // a router ever dispatches to an offline worker).
    check(10, |g| {
        let n = g.usize_range(10, 40);
        let p = g.u64_range(1, 3) as u32;
        let d = g.u64_range(1, 3) as u32;
        let qps = g.f64_range(1.0, 8.0);
        let w = jittered_workload(n, g.u64_range(500, 6000), g.u64_range(8, 64), 0.3, qps, g.case_seed);
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: p,
            decode_gpus: d,
        });
        let mut e = DisaggEngine::new(cfg, p, d, g.case_seed);
        if g.bool(0.5) {
            e.reconfigurable = true;
            e.reconfig_s = g.f64_range(1.0, 10.0);
            e.planner_interval = g.f64_range(5.0, 20.0);
        }
        let rep = e.run(w);
        if rep.completed + e.dropped != n as u64 {
            return Err(format!(
                "{p}P{d}D lost requests: {} + {} != {n}",
                rep.completed, e.dropped
            ));
        }
        e.check_invariants()
            .map_err(|m| format!("{p}P{d}D: {m}"))?;
        for r in &e.finished {
            if r.finished_at.unwrap_or(f64::NEG_INFINITY) < r.arrival {
                return Err(format!("{p}P{d}D: request {} finished before arrival", r.id));
            }
        }
        Ok(())
    });
}

#[test]
fn replicated_clusters_conserve_requests_across_routers() {
    // The same conservation + causality properties over unified-replica
    // topologies, for every router policy.
    check(12, |g| {
        let n = g.usize_range(8, 32);
        let replicas = g.u64_range(1, 4) as u32;
        let qps = g.f64_range(1.0, 15.0);
        let isl = g.u64_range(64, 8000);
        let osl = g.u64_range(1, 64);
        let routers = ["round-robin", "least-outstanding", "kv-pressure"];
        let router = *g.choose(&routers);
        let w = jittered_workload(n, isl, osl, 0.3, qps, g.case_seed);
        let total_out: u64 = w.requests.iter().map(|r| r.output_len).sum();

        let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let mut e = ReplicatedEngine::new(cfg, replicas, g.case_seed)
            .with_router(router_by_name(router).expect("known router"));
        let rep = e.run(w);

        let label = format!("{replicas}x/{router}");
        e.check_invariants().map_err(|m| format!("{label}: {m}"))?;
        if rep.completed + e.dropped != n as u64 {
            return Err(format!(
                "{label}: lost requests: completed {} + dropped {} != {n}",
                rep.completed, e.dropped
            ));
        }
        if e.dropped == 0 && e.metrics.output_tokens != total_out {
            return Err(format!(
                "{label}: token conservation: {} != {total_out}",
                e.metrics.output_tokens
            ));
        }
        for r in &e.finished {
            if r.first_token_at.unwrap_or(f64::NEG_INFINITY) < r.arrival {
                return Err(format!(
                    "{label}: request {} produced a token before its arrival",
                    r.id
                ));
            }
        }
        Ok(())
    });
}

/// The steppable-loop property: feeding the cluster one request at a
/// time as its clock reaches each arrival (the live-serving pattern:
/// `inject` when due, `step_next` with the next-arrival hint) produces
/// exactly the same merged report as the batch `run(workload)` replay —
/// there is one event loop, entered two ways.
#[test]
fn cluster_batch_run_equals_incremental_live_feed() {
    check(8, |g| {
        let n = g.usize_range(8, 28);
        let isl = g.u64_range(64, 8000);
        let osl = g.u64_range(1, 48);
        let qps = g.f64_range(1.0, 14.0);
        let replicas = g.u64_range(1, 4) as u32;
        let routers = ["round-robin", "least-outstanding", "kv-pressure"];
        let router = *g.choose(&routers);
        let seed = g.case_seed;
        let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let w = jittered_workload(n, isl, osl, 0.3, qps, seed).sorted_by_arrival();

        let mut batch = ClusterEngine::replicated(
            cfg.clone(),
            replicas,
            seed,
            router_by_name(router).expect("known router"),
        );
        let rep_batch = batch.run(w.clone());

        let mut live = ClusterEngine::replicated(
            cfg,
            replicas,
            seed,
            router_by_name(router).expect("known router"),
        );
        let mut feed: VecDeque<Request> = w.requests.into();
        loop {
            while feed.front().is_some_and(|r| r.arrival <= live.clock()) {
                live.inject(feed.pop_front().unwrap());
            }
            let hint = feed.front().map(|r| r.arrival);
            match live.step_next(hint) {
                TopologyStep::Exhausted => break,
                TopologyStep::Diverged(_) => {
                    feed.clear();
                    break;
                }
                _ => {}
            }
        }
        let rep_live = live.drain();

        let label = format!("{replicas}x/{router}");
        live.check_invariants().map_err(|m| format!("{label}: {m}"))?;
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()));
        if rep_live.completed != rep_batch.completed {
            return Err(format!(
                "{label}: completed {} != batch {}",
                rep_live.completed, rep_batch.completed
            ));
        }
        if rep_live.iterations != rep_batch.iterations {
            return Err(format!(
                "{label}: iterations {} != batch {}",
                rep_live.iterations, rep_batch.iterations
            ));
        }
        if !close(rep_live.duration, rep_batch.duration) {
            return Err(format!(
                "{label}: duration {} != batch {}",
                rep_live.duration, rep_batch.duration
            ));
        }
        if !close(rep_live.ttft.mean, rep_batch.ttft.mean)
            || !close(rep_live.tbt.mean, rep_batch.tbt.mean)
        {
            return Err(format!(
                "{label}: latency drift: ttft {} vs {}, tbt {} vs {}",
                rep_live.ttft.mean, rep_batch.ttft.mean, rep_live.tbt.mean, rep_batch.tbt.mean
            ));
        }
        Ok(())
    });
}

#[test]
fn deterministic_given_seed() {
    let w = |seed| -> Workload { jittered_workload(25, 3000, 48, 0.2, 6.0, seed) };
    let cfg = ServingConfig::default_8b().with_policy(Policy::Duet);
    let mut e1 = engine_for(cfg.clone(), 9);
    let r1 = e1.run(w(4));
    let mut e2 = engine_for(cfg, 9);
    let r2 = e2.run(w(4));
    assert_eq!(r1.completed, r2.completed);
    assert_eq!(r1.iterations, r2.iterations);
    assert!((r1.duration - r2.duration).abs() < 1e-12);
    assert!((r1.tbt.mean - r2.tbt.mean).abs() < 1e-12);
}

#[test]
fn tp_scaling_reduces_latency_for_14b() {
    // TP=2 must strictly improve iteration latency for a compute-heavy
    // workload on the same policy (paper Fig. 7 setting).
    let w = jittered_workload(20, 6000, 32, 0.2, 4.0, 11);
    let m = ModelSpec::qwen3_14b();
    let mut e1 = engine_for(
        ServingConfig::default_8b()
            .with_model(m.clone(), 1)
            .with_policy(Policy::VllmChunked),
        3,
    );
    let r1 = e1.run(w.clone());
    let mut e2 = engine_for(
        ServingConfig::default_8b()
            .with_model(m, 2)
            .with_policy(Policy::VllmChunked),
        3,
    );
    let r2 = e2.run(w);
    assert!(
        r2.e2e.mean < r1.e2e.mean,
        "TP=2 e2e {} should beat TP=1 {}",
        r2.e2e.mean,
        r1.e2e.mean
    );
}

#[test]
fn kv_pressure_triggers_preemption_not_corruption() {
    // Tiny KV: the engine must survive via recompute preemption and still
    // finish everything.
    let mut cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    cfg.gpu_mem_util = 0.22; // barely any KV headroom beyond weights
    let kv_tokens = cfg.kv_capacity_tokens();
    assert!(kv_tokens > 2000, "test needs some KV: {kv_tokens}");
    let mut e = engine_for(cfg, 5);
    // Prompts sized so ~3 fit concurrently; outputs long enough to grow.
    let w = jittered_workload(12, kv_tokens / 3, 256, 0.1, 50.0, 5);
    let rep = e.run(w);
    assert_eq!(rep.completed + e.dropped, 12);
    assert!(rep.completed >= 10, "most requests should finish");
    e.check_invariants().unwrap();
}
