//! HTTP transport integration: real sockets against a spawned
//! `serve-http`-equivalent server. Covers the acceptance criterion that
//! the drained `metrics::Report` of an HTTP-served run matches an
//! equivalent in-process `ServerCore` run (same trace + seed) — on both
//! the readiness-polled keep-alive pool and the thread-per-connection
//! baseline — plus the error-code mapping, queue-cap backpressure over
//! the wire, client-disconnect cancellation, keep-alive reuse semantics
//! (sequential, pipelined, malformed, idle-timeout, `--max-conns`), and
//! the cluster- and shard-backed front doors.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use duetserve::config::{Policy, ServingConfig};
use duetserve::server::http::{HttpConfig, HttpServer};
use duetserve::server::{Server, ServerCore, ShardedServer, SubmitOptions};
use duetserve::util::json::{self, Json};
use duetserve::workload::synthetic::jittered_workload;

fn cfg() -> ServingConfig {
    ServingConfig::default_8b().with_policy(Policy::VllmChunked)
}

/// Both accept paths, by pool size: `0` is the thread-per-connection
/// baseline, anything else the readiness-polled keep-alive pool.
const BOTH_PATHS: [usize; 2] = [0, 2];

fn start_http_with(
    c: ServingConfig,
    seed: u64,
    queue_cap: usize,
    max_body: usize,
    pool_workers: usize,
) -> HttpServer {
    let server =
        Server::start(move || Ok(ServerCore::sim(c, seed).with_queue_depth(queue_cap))).unwrap();
    HttpServer::start(
        "127.0.0.1:0",
        server,
        HttpConfig {
            max_body,
            pool_workers,
            ..Default::default()
        },
    )
    .unwrap()
}

fn start_http(c: ServingConfig, seed: u64, queue_cap: usize, max_body: usize) -> HttpServer {
    start_http_with(c, seed, queue_cap, max_body, 2)
}

fn connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    s
}

fn request_bytes(method: &str, path: &str, body: Option<&str>, close: bool) -> String {
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: x\r\n");
    if close {
        req.push_str("Connection: close\r\n");
    }
    if let Some(b) = body {
        req.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            b.len()
        ));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    req
}

fn parse_status(resp: &str) -> u16 {
    resp.split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("no status line in `{resp}`"))
}

/// One `Connection: close` request/response exchange over a fresh
/// connection; the server's close is the response delimiter, which is
/// why the helper works identically on both accept paths.
fn exchange(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let (status, resp) = exchange_raw(addr, method, path, body);
    let payload = resp
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, payload)
}

/// Like [`exchange`] but returns the whole raw response (status line,
/// headers and body) for byte-level comparisons.
fn exchange_raw(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut s = connect(addr);
    s.write_all(request_bytes(method, path, body, true).as_bytes())
        .unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    (parse_status(&resp), resp)
}

/// One request/response exchange on an already-open keep-alive socket:
/// the response is read by its `Content-Length` framing (not EOF), so
/// the socket stays usable for the next call.
fn keep_alive_exchange(
    r: &mut BufReader<TcpStream>,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> (u16, String, String) {
    r.get_mut()
        .write_all(request_bytes(method, path, body, false).as_bytes())
        .unwrap();
    read_framed_response(r)
}

/// Read one `Content-Length`-framed response; returns (status, raw head
/// + body, body).
fn read_framed_response(r: &mut BufReader<TcpStream>) -> (u16, String, String) {
    let mut head = String::new();
    loop {
        let mut line = String::new();
        assert!(r.read_line(&mut line).unwrap() > 0, "EOF inside head");
        head.push_str(&line);
        if line == "\r\n" || line == "\n" {
            break;
        }
    }
    let status = parse_status(&head);
    let len: usize = head
        .lines()
        .find_map(|l| l.to_ascii_lowercase().strip_prefix("content-length:").map(str::to_string))
        .and_then(|v| v.trim().parse().ok())
        .expect("framed response needs a content-length");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).unwrap();
    let body = String::from_utf8(body).unwrap();
    (status, format!("{head}{body}"), body)
}

/// Open a streaming completion and return the reader once the 200
/// status line has arrived (headers/frames still unread).
fn open_sse(addr: SocketAddr, body: &str) -> BufReader<TcpStream> {
    let mut s = connect(addr);
    write!(
        s,
        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nConnection: close\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut r = BufReader::new(s);
    let mut status = String::new();
    r.read_line(&mut status).unwrap();
    assert!(status.starts_with("HTTP/1.1 200"), "streaming got {status}");
    r
}

/// Consume SSE frames to `[DONE]`; returns (token ids, finish reason).
fn read_sse(r: BufReader<TcpStream>) -> (Vec<i64>, String) {
    let mut toks = Vec::new();
    let mut finish = String::new();
    for line in r.lines() {
        let line = line.unwrap();
        let Some(p) = line.strip_prefix("data: ") else {
            continue;
        };
        if p == "[DONE]" {
            break;
        }
        let v = json::parse(p).unwrap_or_else(|e| panic!("bad SSE chunk `{p}`: {e}"));
        let c = &v.get("choices").unwrap().as_array().unwrap()[0];
        if let Some(t) = c.get("token_id").and_then(|t| t.as_i64()) {
            toks.push(t);
        } else if let Some(f) = c.get("finish_reason").and_then(|f| f.as_str()) {
            finish = f.to_string();
        }
    }
    (toks, finish)
}

/// Run one streaming completion to `[DONE]`; returns (token ids, finish
/// reason).
fn sse_completion(addr: SocketAddr, body: &str) -> (Vec<i64>, String) {
    read_sse(open_sse(addr, body))
}

fn prompt_tokens(n: usize) -> Vec<i32> {
    (0..n).map(|i| (i % 997) as i32).collect()
}

fn completion_body(prompt: &[i32], max_tokens: u64, arrival: f64, stream: bool) -> String {
    Json::obj(vec![
        (
            "prompt",
            Json::arr(prompt.iter().map(|t| Json::Num(f64::from(*t))).collect()),
        ),
        ("max_tokens", Json::Num(max_tokens as f64)),
        ("arrival", Json::Num(arrival)),
        ("stream", Json::Bool(stream)),
    ])
    .dump()
}

/// The acceptance property: serving a trace over real sockets (mixed
/// streaming and non-streaming, sequential so the interaction order is
/// deterministic) produces the same token values and the same drained
/// `Report` as an equivalent in-process `ServerCore` run with the same
/// trace and seed — on *both* accept paths (pool and baseline).
#[test]
fn http_run_matches_in_process_server_core() {
    let seed = 11;
    let w = jittered_workload(8, 900, 12, 0.3, 5.0, seed).sorted_by_arrival();

    // In-process mirror: same trace, same seed, same submit→drain
    // interaction pattern.
    let mut mirror = ServerCore::sim(cfg(), seed).with_queue_depth(64);
    let mut mirror_tokens: Vec<Vec<i64>> = Vec::new();
    for r in &w.requests {
        let h = mirror
            .submit(
                prompt_tokens(r.prompt_len as usize),
                SubmitOptions {
                    max_new_tokens: r.output_len,
                    arrival: Some(r.arrival),
                    ..Default::default()
                },
            )
            .unwrap();
        mirror.run_to_idle();
        mirror_tokens.push(h.collect().into_iter().map(i64::from).collect());
    }
    let mirror_rep = mirror.finish();

    for pool_workers in BOTH_PATHS {
        // HTTP path: every request fully drained before the next (the
        // response/[DONE] is the barrier), so the engine sees the same
        // submit→idle sequence the in-process mirror replayed above.
        let http = start_http_with(cfg(), seed, 64, 1 << 20, pool_workers);
        let addr = http.addr();
        let mut http_tokens: Vec<Vec<i64>> = Vec::new();
        for (i, r) in w.requests.iter().enumerate() {
            let prompt = prompt_tokens(r.prompt_len as usize);
            let body = completion_body(&prompt, r.output_len, r.arrival, i % 2 == 0);
            if i % 2 == 0 {
                let (toks, finish) = sse_completion(addr, &body);
                assert_eq!(finish, "length", "request {i} (pool {pool_workers})");
                http_tokens.push(toks);
            } else {
                let (status, resp) = exchange(addr, "POST", "/v1/completions", Some(&body));
                assert_eq!(status, 200, "request {i} (pool {pool_workers}): {resp}");
                let v = json::parse(&resp).unwrap();
                let choice = &v.get("choices").unwrap().as_array().unwrap()[0];
                assert_eq!(
                    choice.get("finish_reason").and_then(|f| f.as_str()),
                    Some("length")
                );
                let toks: Vec<i64> = choice
                    .get("token_ids")
                    .unwrap()
                    .as_array()
                    .unwrap()
                    .iter()
                    .map(|t| t.as_i64().unwrap())
                    .collect();
                let usage = v.get("usage").unwrap();
                assert_eq!(
                    usage.get("prompt_tokens").and_then(|p| p.as_u64()),
                    Some(r.prompt_len)
                );
                assert_eq!(
                    usage.get("completion_tokens").and_then(|c| c.as_u64()),
                    Some(toks.len() as u64)
                );
                http_tokens.push(toks);
            }
        }
        let http_rep = http.shutdown().unwrap();

        assert_eq!(
            http_tokens, mirror_tokens,
            "token values must match (pool {pool_workers})"
        );
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()));
        assert_eq!(http_rep.completed, mirror_rep.completed);
        assert_eq!(http_rep.iterations, mirror_rep.iterations);
        assert_eq!(http_rep.queue_cap, Some(64));
        assert_eq!(mirror_rep.queue_cap, Some(64));
        assert!(
            close(http_rep.ttft.mean, mirror_rep.ttft.mean),
            "ttft {} != {} (pool {pool_workers})",
            http_rep.ttft.mean,
            mirror_rep.ttft.mean
        );
        assert!(
            close(http_rep.tbt.mean, mirror_rep.tbt.mean),
            "tbt {} != {} (pool {pool_workers})",
            http_rep.tbt.mean,
            mirror_rep.tbt.mean
        );
        assert!(
            close(http_rep.duration, mirror_rep.duration),
            "duration {} != {} (pool {pool_workers})",
            http_rep.duration,
            mirror_rep.duration
        );
        assert_eq!(http_rep.system, mirror_rep.system);
    }
}

#[test]
fn http_error_code_mapping() {
    let http = start_http(cfg(), 3, 8, 4096);
    let addr = http.addr();

    // Unknown route → 404; wrong method on a known route → 405.
    assert_eq!(exchange(addr, "GET", "/nope", None).0, 404);
    assert_eq!(exchange(addr, "GET", "/v1/completions", None).0, 405);
    assert_eq!(exchange(addr, "POST", "/healthz", None).0, 405);

    // Malformed JSON / bad fields → 400.
    let (status, body) = exchange(addr, "POST", "/v1/completions", Some("{not json"));
    assert_eq!(status, 400);
    assert!(body.contains("malformed JSON"), "{body}");
    assert_eq!(
        exchange(
            addr,
            "POST",
            "/v1/completions",
            Some(r#"{"prompt":[1],"max_tokens":"six"}"#)
        )
        .0,
        400
    );
    // Validation inside ServerCore (empty prompt) also maps to 400.
    assert_eq!(
        exchange(addr, "POST", "/v1/completions", Some(r#"{"prompt":[]}"#)).0,
        400
    );

    // Unknown SLO class is rejected strictly, not coerced to a default.
    let (status, body) = exchange(
        addr,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt":[1,2],"slo_class":"gold"}"#),
    );
    assert_eq!(status, 400);
    assert!(body.contains("slo_class"), "{body}");

    // Body over the configured cap → 413.
    let big = completion_body(&[7; 2000], 4, 0.0, false);
    assert!(big.len() > 4096);
    let (status, body) = exchange(addr, "POST", "/v1/completions", Some(&big));
    assert_eq!(status, 413);
    assert!(body.contains("4096"), "{body}");

    // Declared content-length longer than the sent body → 400 once the
    // client half-closes.
    let mut s = connect(addr);
    s.write_all(
        b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 50\r\n\r\nshort",
    )
    .unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    assert!(resp.contains("content-length mismatch"), "{resp}");

    // Nothing was ever accepted: the drain report is empty.
    let rep = http.shutdown().unwrap();
    assert_eq!(rep.completed, 0);
}

#[test]
fn healthz_and_metrics_endpoints() {
    let http = start_http(cfg(), 5, 32, 1 << 20);
    let addr = http.addr();

    let (status, body) = exchange(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("status").and_then(|s| s.as_str()), Some("ok"));

    let (toks, finish) = sse_completion(addr, &completion_body(&prompt_tokens(256), 5, 0.0, true));
    assert_eq!(toks.len(), 5);
    assert_eq!(finish, "length");

    let (status, metrics) = exchange(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    for needle in [
        "# TYPE duetserve_http_requests_total counter",
        "duetserve_http_tokens_streamed_total 5",
        "duetserve_queue_cap 32",
        "duetserve_engine_completed_total 1",
        "duetserve_engine_iterations_total",
    ] {
        assert!(metrics.contains(needle), "missing `{needle}` in:\n{metrics}");
    }

    // The live snapshot must be non-destructive: serving continues and
    // the final report still counts everything.
    let (toks, _) = sse_completion(addr, &completion_body(&prompt_tokens(128), 3, 0.0, true));
    assert_eq!(toks.len(), 3);
    let rep = http.shutdown().unwrap();
    assert_eq!(rep.completed, 2);
}

/// Backpressure over the wire (429 once `queued() >= queue-cap`) and
/// client-disconnect cancellation (dropping a streaming connection frees
/// the slot so queued work proceeds).
#[test]
fn http_backpressure_and_disconnect_cancel() {
    let mut c = cfg();
    c.max_batch = 1; // one running slot: everything else queues
    let http = start_http(c, 7, 2, 1 << 20);
    let addr = http.addr();

    // r0: long-running stream; read up to its first token so it is
    // admitted out of the queue before anything else is submitted.
    let mut r0 = open_sse(addr, &completion_body(&prompt_tokens(1000), 30_000, 0.0, true));
    let mut line = String::new();
    loop {
        line.clear();
        r0.read_line(&mut line).unwrap();
        if line.starts_with("data: ") {
            break; // first token streamed → r0 is running
        }
        assert!(!line.is_empty(), "stream ended before first token");
    }

    // r1 and r2 fill the submission queue (cap 2) behind the busy slot;
    // their SSE headers arrive but no tokens yet.
    let r1 = open_sse(addr, &completion_body(&prompt_tokens(64), 8, 0.0, true));
    let r2 = open_sse(addr, &completion_body(&prompt_tokens(64), 8, 0.0, true));

    // r3 must bounce off the full queue with 429.
    let (status, body) = exchange(
        addr,
        "POST",
        "/v1/completions",
        Some(&completion_body(&prompt_tokens(8), 2, 0.0, false)),
    );
    assert_eq!(status, 429, "{body}");
    assert!(body.contains("queue full") || body.contains("queue-cap"), "{body}");

    // Disconnect r0 mid-stream: the transport cancels it server-side,
    // freeing the single slot — which is the only way r1/r2 can finish
    // their 8 tokens (r0 alone would hold the slot for 30k tokens).
    drop(r0);
    let (toks1, finish1) = read_sse(r1);
    assert_eq!((toks1.len(), finish1.as_str()), (8, "length"));
    let (toks2, finish2) = read_sse(r2);
    assert_eq!((toks2.len(), finish2.as_str()), (8, "length"));

    // Only r1 and r2 completed; r0 was cancelled, r3 never accepted.
    let rep = http.shutdown().unwrap();
    assert_eq!(rep.completed, 2);
}

/// QoS API compatibility: a pre-QoS request body (no `slo_class`,
/// `priority`, or SLO fields) and the same request re-expressed through
/// the new surface with its documented defaults (`"slo_class":
/// "standard"`, `"priority": 0`) must produce byte-identical responses
/// from identically-seeded servers — the redesigned submission API maps
/// legacy bodies onto the standard class with no behavioral drift.
#[test]
fn legacy_body_matches_explicit_standard_class_byte_for_byte() {
    let seed = 33;
    let legacy_srv = start_http(cfg(), seed, 32, 1 << 20);
    let explicit_srv = start_http(cfg(), seed, 32, 1 << 20);

    for i in 0..3 {
        let prompt = prompt_tokens(300 + 64 * i);
        let legacy_body = completion_body(&prompt, 6, 0.0, false);
        let explicit_body = Json::obj(vec![
            (
                "prompt",
                Json::arr(prompt.iter().map(|t| Json::Num(f64::from(*t))).collect()),
            ),
            ("max_tokens", Json::Num(6.0)),
            ("arrival", Json::Num(0.0)),
            ("stream", Json::Bool(false)),
            ("slo_class", Json::string("standard")),
            ("priority", Json::Num(0.0)),
        ])
        .dump();
        let (ls, legacy_raw) =
            exchange_raw(legacy_srv.addr(), "POST", "/v1/completions", Some(&legacy_body));
        let (es, explicit_raw) = exchange_raw(
            explicit_srv.addr(),
            "POST",
            "/v1/completions",
            Some(&explicit_body),
        );
        assert_eq!((ls, es), (200, 200), "request {i}");
        assert_eq!(
            legacy_raw, explicit_raw,
            "request {i}: legacy and explicit-standard responses must be byte-identical"
        );
    }

    let legacy_rep = legacy_srv.shutdown().unwrap();
    let explicit_rep = explicit_srv.shutdown().unwrap();
    assert_eq!(legacy_rep.completed, 3);
    assert_eq!(format!("{legacy_rep:?}"), format!("{explicit_rep:?}"));
}

/// The transport composes with a routed multi-worker cluster: the same
/// wire surface over `ServerCore::sim_replicated`, with the merged
/// cross-worker drain report coming back from `/shutdown`.
#[test]
fn http_over_replicated_cluster() {
    let server = Server::start_sim_replicated(cfg(), 2, 9, "least-outstanding").unwrap();
    let http = HttpServer::start("127.0.0.1:0", server, HttpConfig::default()).unwrap();
    let addr = http.addr();
    for i in 0..6 {
        let body = completion_body(&prompt_tokens(512 + 128 * (i % 3)), 6, 0.0, false);
        let (status, resp) = exchange(addr, "POST", "/v1/completions", Some(&body));
        assert_eq!(status, 200, "{resp}");
        let v = json::parse(&resp).unwrap();
        let choice = &v.get("choices").unwrap().as_array().unwrap()[0];
        assert_eq!(
            choice.get("token_ids").unwrap().as_array().unwrap().len(),
            6
        );
    }
    // /metrics over a cluster exercises the non-destructive cross-worker
    // snapshot.
    let (status, metrics) = exchange(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(metrics.contains("duetserve_engine_completed_total 6"), "{metrics}");

    // Drain over the wire; the response body is the merged report.
    let (status, report) = exchange(addr, "POST", "/shutdown", None);
    assert_eq!(status, 200);
    let v = json::parse(&report).unwrap();
    assert_eq!(v.get("completed").and_then(|c| c.as_u64()), Some(6));
    let system = v.get("system").and_then(|s| s.as_str()).unwrap().to_string();
    assert!(system.contains("x2"), "cluster label missing: {system}");
    let rep = http.join().unwrap();
    assert_eq!(rep.completed, 6);
    assert!(rep.system.contains("x2"));
}

#[cfg(unix)]
fn start_http_cfg(c: ServingConfig, seed: u64, http_cfg: HttpConfig) -> HttpServer {
    let server = Server::start(move || Ok(ServerCore::sim(c, seed).with_queue_depth(64))).unwrap();
    HttpServer::start("127.0.0.1:0", server, http_cfg).unwrap()
}

/// Keep-alive reuse: N sequential completions on one socket produce the
/// same responses as N fresh-connection completions against an
/// identically-seeded server — and the final (`Connection: close`)
/// response is *byte-identical* between the two, pinning that both
/// accept paths share one response builder.
#[cfg(unix)]
#[test]
fn keep_alive_socket_matches_fresh_connections_byte_for_byte() {
    let seed = 21;
    let reused = start_http(cfg(), seed, 32, 1 << 20);
    let fresh = start_http(cfg(), seed, 32, 1 << 20);

    let bodies: Vec<String> = (0..3)
        .map(|i| completion_body(&prompt_tokens(300 + 50 * i), 6, 0.0, false))
        .collect();

    // One kept-alive socket, requests 1..N framed by Content-Length;
    // the last request asks to close, so its response is EOF-delimited.
    let mut r = BufReader::new(connect(reused.addr()));
    let mut reused_bodies = Vec::new();
    for body in &bodies[..bodies.len() - 1] {
        let (status, _raw, payload) =
            keep_alive_exchange(&mut r, "POST", "/v1/completions", Some(body));
        assert_eq!(status, 200, "{payload}");
        reused_bodies.push(payload);
    }
    let last = bodies.last().unwrap();
    r.get_mut()
        .write_all(request_bytes("POST", "/v1/completions", Some(last), true).as_bytes())
        .unwrap();
    let mut reused_last_raw = String::new();
    r.read_to_string(&mut reused_last_raw).unwrap();
    assert_eq!(parse_status(&reused_last_raw), 200);

    // Fresh connection per request against the twin server.
    let mut fresh_bodies = Vec::new();
    for body in &bodies[..bodies.len() - 1] {
        let (status, payload) = exchange(fresh.addr(), "POST", "/v1/completions", Some(body));
        assert_eq!(status, 200, "{payload}");
        fresh_bodies.push(payload);
    }
    let (_, fresh_last_raw) = exchange_raw(fresh.addr(), "POST", "/v1/completions", Some(last));

    assert_eq!(reused_bodies, fresh_bodies, "kept-alive responses must match fresh ones");
    assert_eq!(
        reused_last_raw, fresh_last_raw,
        "Connection: close responses must be byte-identical across reuse patterns"
    );

    assert!(
        reused
            .stats()
            .keepalive_reuse_total
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 2,
        "reused socket must count keep-alive reuse"
    );
    assert_eq!(reused.shutdown().unwrap().completed, 3);
    assert_eq!(fresh.shutdown().unwrap().completed, 3);
}

/// Two requests written in a single TCP segment are parsed and answered
/// in order off the same buffered read (HTTP/1.1 pipelining).
#[cfg(unix)]
#[test]
fn pipelined_requests_in_one_write_are_served_in_order() {
    let http = start_http(cfg(), 23, 8, 1 << 20);
    let mut s = connect(http.addr());
    let mut wire = request_bytes("GET", "/healthz", None, false);
    wire.push_str(&request_bytes("GET", "/healthz", None, true));
    s.write_all(wire.as_bytes()).unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert_eq!(resp.matches("HTTP/1.1 200 OK").count(), 2, "{resp}");
    assert!(resp.contains("Connection: keep-alive"), "{resp}");
    assert!(resp.contains("Connection: close"), "{resp}");
    assert_eq!(http.shutdown().unwrap().completed, 0);
}

/// A malformed request on a kept-alive socket gets `400` and closes that
/// connection — without disturbing other connections multiplexed on the
/// same pool worker.
#[cfg(unix)]
#[test]
fn malformed_request_closes_only_its_own_connection() {
    // One pool worker, so both sockets share a readiness loop.
    let http = start_http_with(cfg(), 25, 8, 1 << 20, 1);
    let mut a = BufReader::new(connect(http.addr()));
    let mut b = BufReader::new(connect(http.addr()));
    let (st, _, _) = keep_alive_exchange(&mut a, "GET", "/healthz", None);
    assert_eq!(st, 200);
    let (st, _, _) = keep_alive_exchange(&mut b, "GET", "/healthz", None);
    assert_eq!(st, 200);

    // Garbage on A: 400 then EOF.
    a.get_mut().write_all(b"GARBAGE\r\n\r\n").unwrap();
    let mut resp = String::new();
    a.read_to_string(&mut resp).unwrap();
    assert_eq!(parse_status(&resp), 400, "{resp}");

    // B is untouched: still serving on the same worker.
    let (st, _, _) = keep_alive_exchange(&mut b, "GET", "/healthz", None);
    assert_eq!(st, 200);
    drop(a);
    drop(b);
    assert_eq!(http.shutdown().unwrap().completed, 0);
}

/// `--max-conns`: accepts beyond the cap are answered `503` +
/// `Connection: close` without touching a pool worker; closing a held
/// connection frees the slot.
#[cfg(unix)]
#[test]
fn max_conns_cap_refuses_excess_accepts_with_503() {
    let http = start_http_cfg(
        cfg(),
        27,
        HttpConfig {
            max_conns: 1,
            pool_workers: 1,
            ..Default::default()
        },
    );
    let addr = http.addr();
    // Register one kept-alive connection (the exchange proves it's in).
    let mut held = BufReader::new(connect(addr));
    let (st, _, _) = keep_alive_exchange(&mut held, "GET", "/healthz", None);
    assert_eq!(st, 200);

    // The next accept must bounce with 503 + close.
    let (status, raw) = exchange_raw(addr, "GET", "/healthz", None);
    assert_eq!(status, 503, "{raw}");
    assert!(raw.contains("Connection: close"), "{raw}");
    assert!(raw.contains("max-conns"), "{raw}");
    assert!(raw.contains("Retry-After"), "{raw}");

    // Release the held slot; the cap admits a new connection again
    // (registration is asynchronous, so poll briefly).
    drop(held);
    let t0 = Instant::now();
    loop {
        let (status, _) = exchange_raw(addr, "GET", "/healthz", None);
        if status == 200 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "slot never freed after closing the held connection"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(http.shutdown().unwrap().completed, 0);
}

/// A kept-alive connection idle past the configured timeout is closed by
/// the server (counted reap, not a hang).
#[cfg(unix)]
#[test]
fn idle_keep_alive_connection_times_out() {
    let http = start_http_cfg(
        cfg(),
        29,
        HttpConfig {
            idle_timeout: Duration::from_millis(200),
            pool_workers: 1,
            ..Default::default()
        },
    );
    let mut s = BufReader::new(connect(http.addr()));
    let (st, _, _) = keep_alive_exchange(&mut s, "GET", "/healthz", None);
    assert_eq!(st, 200);
    // Park the socket: the server must close it around the idle timeout.
    let t0 = Instant::now();
    let mut rest = String::new();
    s.read_to_string(&mut rest).unwrap();
    assert!(rest.is_empty(), "no further bytes expected, got `{rest}`");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "idle close took {:?}",
        t0.elapsed()
    );
    assert_eq!(http.shutdown().unwrap().completed, 0);
}

/// Sharded submission: N engine shards behind one HTTP front door, with
/// round-robin shard routing, strided globally-unique request ids, and a
/// merged drain report.
#[test]
fn sharded_front_door_routes_and_merges_reports() {
    let sharded = ShardedServer::start(2, "round-robin", |i| {
        let c = cfg();
        move || {
            Ok(ServerCore::sim(c, 31 + i as u64)
                .with_queue_depth(32)
                .with_id_stride(i as u64 + 1, 2))
        }
    })
    .unwrap();
    let http = HttpServer::start("127.0.0.1:0", sharded, HttpConfig::default()).unwrap();
    let addr = http.addr();

    let mut ids = std::collections::BTreeSet::new();
    for i in 0..6 {
        let body = completion_body(&prompt_tokens(256 + 64 * (i % 2)), 4, 0.0, false);
        let (status, resp) = exchange(addr, "POST", "/v1/completions", Some(&body));
        assert_eq!(status, 200, "{resp}");
        let v = json::parse(&resp).unwrap();
        let id = v.get("id").and_then(|x| x.as_str()).unwrap().to_string();
        assert!(ids.insert(id), "request ids must be globally unique across shards");
        let choice = &v.get("choices").unwrap().as_array().unwrap()[0];
        assert_eq!(choice.get("token_ids").unwrap().as_array().unwrap().len(), 4);
    }
    assert_eq!(ids.len(), 6);

    // Live merged snapshot across shards.
    let (status, metrics) = exchange(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(
        metrics.contains("duetserve_engine_completed_total 6"),
        "{metrics}"
    );

    let rep = http.shutdown().unwrap();
    assert_eq!(rep.completed, 6);
    assert!(rep.system.contains("2x"), "shard label missing: {}", rep.system);
}
