//! Integration: rust PJRT runtime loads the python-AOT artifacts and the
//! decode path is consistent with prefill (the interchange contract's
//! rust half). Skips gracefully if `make artifacts` has not run.

use duetserve::config::{Policy, ServingConfig};
use duetserve::runtime::{artifacts, PjrtBackend, TinyRuntime};
use duetserve::sched::{scheduler_for, SglangDefaultScheduler};
use duetserve::server::{RequestHandle, ServerCore, SubmitOptions};

fn runtime_or_skip() -> Option<TinyRuntime> {
    if !artifacts::artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(TinyRuntime::load_default().expect("load artifacts"))
}

#[test]
fn prefill_executes_and_is_deterministic() {
    let Some(rt) = runtime_or_skip() else { return };
    let prompt = [5i32, 99, 1023, 7, 300, 12];
    let a = rt.prefill(&prompt).unwrap();
    let b = rt.prefill(&prompt).unwrap();
    assert_eq!(a.next_token, b.next_token);
    assert_eq!(a.k, b.k);
    assert!((0..rt.meta.vocab as i32).contains(&a.next_token));
}

#[test]
fn decode_continues_prefill_consistently() {
    // Greedy generation via rust PJRT must equal extending the prompt and
    // re-prefilling — the same consistency check python tests do, now
    // across the AOT boundary.
    let Some(mut rt) = runtime_or_skip() else { return };
    let prompt = vec![11i32, 500, 42, 1999, 8];
    let pre = rt.prefill(&prompt).unwrap();
    rt.install_slot(0, prompt.len(), &pre.k, &pre.v);

    let mut tokens = [0i32; 8];
    let mut lengths = [0i32; 8];
    tokens[0] = pre.next_token;
    lengths[0] = prompt.len() as i32;
    let next = rt.decode_step(&tokens, &lengths).unwrap();

    // Ground truth: prefill over prompt + first generated token.
    let mut ext = prompt.clone();
    ext.push(pre.next_token);
    let pre2 = rt.prefill(&ext).unwrap();
    assert_eq!(
        next[0], pre2.next_token,
        "decode-step token must match extended prefill"
    );
}

#[test]
fn inactive_slots_do_not_disturb_active_ones() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let prompt = vec![3i32, 1, 4, 1, 5];
    let pre = rt.prefill(&prompt).unwrap();

    // Run with only slot 0 active.
    rt.install_slot(0, prompt.len(), &pre.k, &pre.v);
    let mut tokens = [0i32; 8];
    let mut lengths = [0i32; 8];
    tokens[0] = pre.next_token;
    lengths[0] = prompt.len() as i32;
    let solo = rt.decode_step(&tokens, &lengths).unwrap()[0];

    // Fresh runtime: slot 0 active plus garbage tokens in inactive slots.
    let mut rt2 = TinyRuntime::load_default().unwrap();
    let pre2 = rt2.prefill(&prompt).unwrap();
    rt2.install_slot(0, prompt.len(), &pre2.k, &pre2.v);
    let mut tokens2 = [777i32; 8];
    let mut lengths2 = [0i32; 8];
    tokens2[0] = pre2.next_token;
    lengths2[0] = prompt.len() as i32;
    let crowded = rt2.decode_step(&tokens2, &lengths2).unwrap()[0];
    assert_eq!(solo, crowded, "inactive slots must be isolated");
}

/// Serve a fixed batch through the unified lifecycle (ServerCore +
/// PjrtBackend) under one scheduler; return (id, tokens) pairs.
fn serve_unified(prefill_first: bool) -> Vec<(u64, Vec<i32>)> {
    let backend = PjrtBackend::load_default().unwrap();
    let cfg = backend.tune_config(ServingConfig::default_8b().with_policy(Policy::VllmChunked));
    let scheduler: Box<dyn duetserve::sched::Scheduler> = if prefill_first {
        Box::new(SglangDefaultScheduler::new(
            2 * cfg.token_budget as u64,
            cfg.max_batch as usize,
        ))
    } else {
        scheduler_for(&cfg)
    };
    let mut core = ServerCore::new(cfg, scheduler, Box::new(backend));
    let handles: Vec<RequestHandle> = (0..6u64)
        .map(|i| {
            core.submit(
                vec![(i as i32 * 37 + 11) % 2048, 5, 9, 2 + i as i32],
                SubmitOptions {
                    max_new_tokens: 6,
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    core.run_to_idle();
    assert_eq!(core.engine().metrics.completed, 6);
    core.engine().check_invariants().unwrap();
    handles
        .into_iter()
        .map(|h| (h.id(), h.collect()))
        .collect()
}

#[test]
fn unified_server_serves_real_tokens_schedule_invariantly() {
    if runtime_or_skip().is_none() {
        return;
    }
    let decode_priority = serve_unified(false);
    let prefill_priority = serve_unified(true);
    for (_, toks) in &decode_priority {
        assert_eq!(toks.len(), 6);
    }
    // Scheduling order differs but greedy tokens are model-determined.
    assert_eq!(
        decode_priority, prefill_priority,
        "tokens must be schedule-invariant"
    );
}
