//! Cross-layer pipeline tests: the simulated evaluation path end to end
//! (workload -> scheduler -> executor -> metrics), checking the paper's
//! qualitative claims hold on fresh seeds (not the bench seeds).

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{engine_for, DisaggEngine, IterKind, ReplicatedEngine};
use duetserve::workload::synthetic::fixed_workload;
use duetserve::workload::traces::{generate, TraceKind};

/// Observation 1+2 end-to-end: under prefill-heavy saturation, DuetServe
/// holds p99 TBT well below the chunked-prefill baseline.
#[test]
fn duet_bounds_tail_tbt_under_prefill_pressure() {
    let w = fixed_workload(30, 8000, 96, 8.0, 314);
    let mut ev = engine_for(
        ServingConfig::default_8b().with_policy(Policy::VllmChunked),
        2,
    );
    let rv = ev.run(w.clone());
    let mut ed = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 2);
    let rd = ed.run(w);
    assert!(rd.spatial_iterations > 0);
    assert!(
        rd.tbt_p99 < 0.85 * rv.tbt_p99,
        "duet p99 {:.0}ms vs vllm {:.0}ms",
        rd.tbt_p99 * 1e3,
        rv.tbt_p99 * 1e3
    );
    // and throughput is not sacrificed
    assert!(rd.throughput_rps > 0.9 * rv.throughput_rps);
}

/// Observation 3 end-to-end: disaggregation satisfies TBT but wastes
/// capacity relative to 2-replica aggregation on a prefill-heavy load.
#[test]
fn disagg_underutilizes_vs_aggregated() {
    let w = fixed_workload(40, 8000, 200, 7.0, 217);
    let mut agg = ReplicatedEngine::new(
        ServingConfig::default_8b().with_policy(Policy::VllmChunked),
        2,
        3,
    );
    let ra = agg.run(w.clone());
    let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
        prefill_gpus: 1,
        decode_gpus: 1,
    });
    let mut dis = DisaggEngine::new(cfg, 1, 1, 3);
    let rd = dis.run(w);
    assert!(rd.tbt.mean < ra.tbt.mean, "disagg protects TBT");
    assert!(
        ra.token_throughput > 1.2 * rd.token_throughput,
        "agg {} tok/s vs disagg {}",
        ra.token_throughput,
        rd.token_throughput
    );
}

/// DuetServe reverts to aggregated execution when contention subsides
/// (decode-heavy regime, Appendix A Table 2 narrative).
#[test]
fn duet_stays_aggregated_when_decode_dominant() {
    let w = fixed_workload(30, 256, 512, 4.0, 99);
    let mut e = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 1);
    let rep = e.run(w);
    let frac = rep.spatial_iterations as f64 / rep.iterations.max(1) as f64;
    assert!(
        frac < 0.05,
        "decode-dominant workload should rarely go spatial: {frac}"
    );
}

/// The engine alternates between spatial and aggregated iterations as
/// load fluctuates (Fig. 10 behaviour) — both kinds must appear in a
/// bursty trace, and every spatial plan must be a valid partition.
#[test]
fn duet_alternates_modes_and_partitions_are_valid() {
    let mut e = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 4);
    e.log_events = true;
    let w = generate(TraceKind::AzureCode, Some(80), 10.0, 12);
    e.run(w);
    let mut spatial = 0;
    let mut agg = 0;
    for ev in &e.events {
        match ev.kind {
            IterKind::Spatial {
                decode_tpcs,
                prefill_tpcs,
                k,
            } => {
                spatial += 1;
                assert!(decode_tpcs >= 1 && prefill_tpcs >= 1);
                assert!(decode_tpcs + prefill_tpcs <= 66);
                assert!(k >= 1 && k <= 16);
            }
            IterKind::Aggregated => agg += 1,
        }
    }
    assert!(spatial > 0, "no spatial iterations in a bursty trace");
    assert!(agg > 0, "no aggregated iterations");
}

/// Scheduling overhead stays under the paper's 1 ms budget even on large
/// mixed batches (the Algorithm-1 solve is the hot path).
#[test]
fn scheduling_overhead_under_one_ms() {
    let mut e = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 8);
    let w = fixed_workload(60, 6000, 128, 10.0, 15);
    let rep = e.run(w);
    assert!(
        rep.sched_overhead_per_iter < 1e-3,
        "sched overhead {:.3}ms",
        rep.sched_overhead_per_iter * 1e3
    );
}

/// SGLang-Default's prefill-priority produces the unbounded-TBT pathology
/// the paper plots (p99 far beyond every other system's).
#[test]
fn sglang_default_tail_blowup() {
    let w = generate(TraceKind::AzureCode, Some(80), 12.0, 21);
    let mut es = engine_for(
        ServingConfig::default_8b().with_policy(Policy::SglangDefault),
        1,
    );
    let rs = es.run(w.clone());
    let mut ed = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 1);
    let rd = ed.run(w);
    assert!(
        rs.tbt_p99 > 3.0 * rd.tbt_p99,
        "sglang-default p99 {:.0}ms should dwarf duet {:.0}ms",
        rs.tbt_p99 * 1e3,
        rd.tbt_p99 * 1e3
    );
}
