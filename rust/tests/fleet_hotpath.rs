//! Fleet hot-path equivalence: the heap-driven event queue and the
//! incremental load board must reproduce the retained naive O(N)-scan
//! reference *byte-identically*.
//!
//! Two clusters built identically — one pinned to the naive reference via
//! `set_naive_scan(true)` — are stepped in lockstep and must agree, at
//! every event, on which worker stepped, the step outcome, and every
//! worker clock bit-for-bit, through epoch re-bases (common-delta
//! `shift_all`), worker offline windows, park nudges and prefill→decode
//! transfer routing. Separately, the incrementally maintained load
//! signals must equal recomputed-from-scratch snapshots after randomized
//! inject/step/cancel/re-base sequences under all three routers (the
//! board ≡ recompute assertions live in `ClusterEngine::check_invariants`
//! and `EngineCore::check_invariants`).

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{router_by_name, ClusterEngine, ServingTopology, TopologyStep};
use duetserve::request::Request;
use duetserve::util::proptest::check;
use duetserve::workload::synthetic::jittered_workload;

const ROUTERS: [&str; 3] = ["round-robin", "least-outstanding", "kv-pressure"];

/// Cap on lockstep events so a livelock fails loudly instead of hanging.
const MAX_EVENTS: u64 = 500_000;

fn replicated_pair(n: u32, router: &str, seed: u64) -> (ClusterEngine, ClusterEngine) {
    let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    let fast = ClusterEngine::replicated(cfg.clone(), n, seed, router_by_name(router).unwrap());
    let mut naive = ClusterEngine::replicated(cfg, n, seed, router_by_name(router).unwrap());
    naive.set_naive_scan(true);
    (fast, naive)
}

fn disagg_pair(p: u32, d: u32, router: &str, seed: u64) -> (ClusterEngine, ClusterEngine) {
    let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
        prefill_gpus: p,
        decode_gpus: d,
    });
    let fast = ClusterEngine::disagg(cfg.clone(), p, d, seed, router_by_name(router).unwrap());
    let mut naive = ClusterEngine::disagg(cfg, p, d, seed, router_by_name(router).unwrap());
    naive.set_naive_scan(true);
    (fast, naive)
}

/// Compare every worker clock bit-for-bit.
fn clocks_equal(fast: &ClusterEngine, naive: &ClusterEngine) -> Result<(), String> {
    for (i, (wf, wn)) in fast.workers.iter().zip(naive.workers.iter()).enumerate() {
        if wf.core.clock.to_bits() != wn.core.clock.to_bits() {
            return Err(format!(
                "worker {i} clock diverged: heap {} vs naive {}",
                wf.core.clock, wn.core.clock
            ));
        }
    }
    Ok(())
}

/// Step both clusters until both report `Exhausted`, asserting the event
/// trajectories are identical. Returns the number of events.
fn lockstep_drain(fast: &mut ClusterEngine, naive: &mut ClusterEngine) -> Result<u64, String> {
    let mut events = 0u64;
    loop {
        let sf = fast.step_next(None);
        let sn = naive.step_next(None);
        if sf != sn {
            return Err(format!("event {events}: heap {sf:?} vs naive {sn:?}"));
        }
        if fast.last_stepped() != naive.last_stepped() {
            return Err(format!(
                "event {events}: heap stepped {:?}, naive stepped {:?}",
                fast.last_stepped(),
                naive.last_stepped()
            ));
        }
        clocks_equal(fast, naive).map_err(|e| format!("event {events}: {e}"))?;
        if events % 64 == 0 {
            fast.check_invariants()
                .map_err(|e| format!("event {events}: heap invariants: {e}"))?;
            naive
                .check_invariants()
                .map_err(|e| format!("event {events}: naive invariants: {e}"))?;
        }
        if matches!(sf, TopologyStep::Exhausted | TopologyStep::Diverged(_)) {
            return Ok(events);
        }
        events += 1;
        if events > MAX_EVENTS {
            return Err("event cap exceeded (livelock?)".into());
        }
    }
}

/// Re-base both clusters' clocks by the common-delta shift and verify it
/// happened identically (bit-exact stagger preservation).
fn lockstep_rebase(fast: &mut ClusterEngine, naive: &mut ClusterEngine) -> Result<(), String> {
    let before: Vec<u64> = fast.workers.iter().map(|w| w.core.clock.to_bits()).collect();
    let rf = ServingTopology::rebase_now(fast);
    let rn = ServingTopology::rebase_now(naive);
    if rf != rn {
        return Err(format!("re-base disagreed: heap {rf}, naive {rn}"));
    }
    clocks_equal(fast, naive).map_err(|e| format!("after re-base: {e}"))?;
    if fast.epoch_offset.to_bits() != naive.epoch_offset.to_bits() {
        return Err("epoch_offset diverged after re-base".into());
    }
    if rf {
        // Relative order across workers must be exactly preserved: the
        // same comparison result for every pair, before and after.
        let after: Vec<u64> = fast.workers.iter().map(|w| w.core.clock.to_bits()).collect();
        for i in 0..before.len() {
            for j in (i + 1)..before.len() {
                let cmp_before = f64::from_bits(before[i]).total_cmp(&f64::from_bits(before[j]));
                let cmp_after = f64::from_bits(after[i]).total_cmp(&f64::from_bits(after[j]));
                if cmp_before != cmp_after {
                    return Err(format!(
                        "re-base reordered workers {i} and {j}: {cmp_before:?} -> {cmp_after:?}"
                    ));
                }
            }
        }
        fast.check_invariants()
            .map_err(|e| format!("heap invariants after re-base: {e}"))?;
    }
    Ok(())
}

/// Final merged reports must agree on every deterministic field.
fn reports_equal(fast: &mut ClusterEngine, naive: &mut ClusterEngine) -> Result<(), String> {
    let rf = ServingTopology::fold_report(fast);
    let rn = ServingTopology::fold_report(naive);
    if rf.completed != rn.completed {
        return Err(format!("completed: {} vs {}", rf.completed, rn.completed));
    }
    if rf.iterations != rn.iterations {
        return Err(format!("iterations: {} vs {}", rf.iterations, rn.iterations));
    }
    if rf.duration.to_bits() != rn.duration.to_bits() {
        return Err(format!("duration: {} vs {}", rf.duration, rn.duration));
    }
    if rf.tbt_p99.to_bits() != rn.tbt_p99.to_bits() {
        return Err(format!("tbt_p99: {} vs {}", rf.tbt_p99, rn.tbt_p99));
    }
    if rf.ttft.mean.to_bits() != rn.ttft.mean.to_bits() {
        return Err(format!("ttft mean: {} vs {}", rf.ttft.mean, rn.ttft.mean));
    }
    if rf.engine_epoch != rn.engine_epoch {
        return Err(format!(
            "engine epoch: {} vs {}",
            rf.engine_epoch, rn.engine_epoch
        ));
    }
    Ok(())
}

#[test]
fn heap_trajectory_matches_naive_scan_replicated() {
    let sizes = [1u32, 2, 8, 33];
    check(12, |g| {
        let n = *g.choose(&sizes);
        let router = *g.choose(&ROUTERS);
        let (mut fast, mut naive) = replicated_pair(n, router, g.case_seed);

        // Wave 1: a batch of arrivals drained to exhaustion.
        let reqs = g.usize_range(4, 24);
        let w = jittered_workload(
            reqs,
            g.u64_range(64, 4000),
            g.u64_range(1, 32),
            0.3,
            g.f64_range(1.0, 12.0),
            g.case_seed,
        );
        for r in w.requests {
            fast.inject(r.clone());
            naive.inject(r);
        }
        lockstep_drain(&mut fast, &mut naive).map_err(|e| format!("wave 1 (n={n}): {e}"))?;

        // Epoch re-base between the waves: both clusters shift every
        // clock by the same common delta, bit-exactly.
        lockstep_rebase(&mut fast, &mut naive).map_err(|e| format!("n={n}: {e}"))?;

        // An offline window on a random worker (the reconfiguration
        // downtime path): the loop must jump that worker's clock and
        // routing must exclude it, identically on both sides.
        let k = g.usize_range(0, n as usize - 1);
        let off = g.f64_range(0.1, 5.0);
        fast.workers[k].offline_until = fast.workers[k].core.clock + off;
        naive.workers[k].offline_until = naive.workers[k].core.clock + off;

        // Wave 2: epoch-local arrivals near zero after the re-base.
        let w2 = jittered_workload(
            g.usize_range(2, 12),
            g.u64_range(64, 2000),
            g.u64_range(1, 16),
            0.3,
            g.f64_range(1.0, 8.0),
            g.case_seed ^ 0xBEEF,
        );
        for mut r in w2.requests {
            r.id += 100_000;
            fast.inject(r.clone());
            naive.inject(r);
        }
        lockstep_drain(&mut fast, &mut naive).map_err(|e| format!("wave 2 (n={n}): {e}"))?;

        reports_equal(&mut fast, &mut naive).map_err(|e| format!("reports (n={n}): {e}"))
    });
}

#[test]
fn heap_trajectory_matches_naive_scan_disagg() {
    // Disaggregated topologies exercise what replication cannot: decode
    // workers parking behind the fleet, transfer-ready routing through
    // the in-flight overlay, KV-full bounces, and (when the planner is
    // on) role flips with reconfiguration downtime.
    let shapes = [(1u32, 1u32), (2, 1), (1, 2), (3, 5)];
    check(8, |g| {
        let (p, d) = *g.choose(&shapes);
        let router = *g.choose(&ROUTERS);
        let (mut fast, mut naive) = disagg_pair(p, d, router, g.case_seed);
        if g.bool(0.5) {
            let reconfig = g.f64_range(1.0, 10.0);
            let interval = g.f64_range(5.0, 20.0);
            for c in [&mut fast, &mut naive] {
                c.reconfigurable = true;
                c.reconfig_s = reconfig;
                c.planner_interval = interval;
            }
        }
        let w = jittered_workload(
            g.usize_range(5, 30),
            g.u64_range(200, 6000),
            g.u64_range(4, 48),
            0.3,
            g.f64_range(1.0, 8.0),
            g.case_seed,
        );
        for r in w.requests {
            fast.inject(r.clone());
            naive.inject(r);
        }
        lockstep_drain(&mut fast, &mut naive).map_err(|e| format!("{p}P{d}D: {e}"))?;
        reports_equal(&mut fast, &mut naive).map_err(|e| format!("reports ({p}P{d}D): {e}"))
    });
}

#[test]
fn incremental_load_signals_match_recompute_after_random_ops() {
    // The load board, busy/queue counters, incremental outstanding-token
    // sums and the event queue must all equal recomputed-from-scratch
    // state after arbitrary interleavings of inject / step / cancel /
    // re-base, under every router. `check_invariants` holds the
    // board ≡ recompute assertions (and the per-worker incremental
    // `outstanding` ≡ recompute check inside `EngineCore`).
    check(16, |g| {
        let router = *g.choose(&ROUTERS);
        let disagg = g.bool(0.4);
        let mut cluster = if disagg {
            let p = g.u64_range(1, 3) as u32;
            let d = g.u64_range(1, 3) as u32;
            disagg_pair(p, d, router, g.case_seed).0
        } else {
            replicated_pair(g.u64_range(1, 9) as u32, router, g.case_seed).0
        };

        let mut next_id = 0u64;
        let mut known: Vec<u64> = Vec::new();
        let mut steps = 0u64;
        for _ in 0..g.usize_range(3, 10) {
            // A burst of arrivals around the current clock.
            for _ in 0..g.usize_range(1, 8) {
                let r = Request::new(
                    next_id,
                    ClusterEngine::clock(&cluster) + g.f64_range(0.0, 0.5),
                    g.u64_range(32, 4000),
                    g.u64_range(1, 24),
                );
                known.push(next_id);
                next_id += 1;
                cluster.inject(r);
            }
            // Advance some events.
            for _ in 0..g.usize_range(1, 40) {
                if matches!(
                    cluster.step_next(None),
                    TopologyStep::Exhausted | TopologyStep::Diverged(_)
                ) {
                    break;
                }
                steps += 1;
            }
            // Cancel a random known request (any stage, or already
            // finished — both outcomes are legal; the board must stay
            // consistent either way).
            if !known.is_empty() && g.bool(0.5) {
                let id = *g.choose(&known);
                ServingTopology::cancel(&mut cluster, id);
            }
            // Occasionally force a re-base if the cluster happens to be
            // idle (no-op otherwise).
            if g.bool(0.3) {
                ServingTopology::rebase_now(&mut cluster);
            }
            cluster
                .check_invariants()
                .map_err(|e| format!("after burst ({router}, {steps} steps): {e}"))?;
        }
        // Drain to the end: the final quiescent state must also agree.
        loop {
            match cluster.step_next(None) {
                TopologyStep::Exhausted | TopologyStep::Diverged(_) => break,
                _ => steps += 1,
            }
            if steps > MAX_EVENTS {
                return Err("event cap exceeded (livelock?)".into());
            }
        }
        cluster
            .check_invariants()
            .map_err(|e| format!("after drain ({router}): {e}"))
    });
}

#[test]
fn queued_and_clock_reads_match_naive_scan() {
    // The O(1) reads the serving front-end uses every tick — `queued()`
    // (backpressure) and `clock()` (arrival reference) — must equal the
    // naive fleet folds at every event.
    let (mut fast, mut naive) = replicated_pair(8, "least-outstanding", 7);
    let w = jittered_workload(30, 2000, 24, 0.3, 6.0, 7);
    for r in w.requests {
        fast.inject(r.clone());
        naive.inject(r);
    }
    let mut guard = 0u64;
    loop {
        let done = matches!(
            fast.step_next(None),
            TopologyStep::Exhausted | TopologyStep::Diverged(_)
        );
        naive.step_next(None);
        assert_eq!(
            ServingTopology::queued(&fast),
            ServingTopology::queued(&naive),
            "queued() diverged from naive fold"
        );
        assert_eq!(
            ClusterEngine::clock(&fast).to_bits(),
            ClusterEngine::clock(&naive).to_bits(),
            "clock() diverged from naive fold"
        );
        if done {
            break;
        }
        guard += 1;
        assert!(guard < MAX_EVENTS, "event cap exceeded");
    }
}
