//! Unified front-end integration: token streaming, FCFS admission
//! fairness under backpressure, cancel/drain semantics, and the identity
//! property — the server path and `SimEngine` produce the same metrics
//! for the same workload and seed. Everything runs on the simulated
//! execution backend, so none of these tests require AOT artifacts.

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{engine_for, router_by_name, ClusterEngine};
use duetserve::server::{
    FinishReason, Server, ServerCore, SubmitError, SubmitOptions, TokenEvent,
};
use duetserve::util::proptest::check;
use duetserve::workload::synthetic::jittered_workload;

fn cfg() -> ServingConfig {
    ServingConfig::default_8b().with_policy(Policy::VllmChunked)
}

fn prompt(n: usize) -> Vec<i32> {
    (0..n).map(|i| (i % 997) as i32).collect()
}

#[test]
fn streams_tokens_and_terminates() {
    let server = Server::start_sim(cfg(), 4).unwrap();
    let handle = server
        .submit(
            vec![5, 99, 1023, 7, 300, 12],
            SubmitOptions {
                max_new_tokens: 6,
                ..Default::default()
            },
        )
        .unwrap();
    let toks = handle.collect();
    assert_eq!(toks.len(), 6);
    server.shutdown().unwrap();
}

#[test]
fn concurrent_submissions_all_complete() {
    let server = Server::start_sim(cfg(), 4).unwrap();
    let handles: Vec<_> = (0..12)
        .map(|i| {
            server
                .submit(
                    prompt(64 + i * 31),
                    SubmitOptions {
                        max_new_tokens: 5,
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    for h in handles {
        assert_eq!(h.collect().len(), 5);
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.completed, 12);
}

#[test]
fn try_next_is_nonblocking() {
    let server = Server::start_sim(cfg(), 1).unwrap();
    let handle = server
        .submit(
            vec![1, 2, 3],
            SubmitOptions {
                max_new_tokens: 3,
                ..Default::default()
            },
        )
        .unwrap();
    // Either nothing yet or an event — must not hang.
    let _ = handle.try_next();
    let mut n = 0;
    loop {
        match handle.try_next() {
            Some(TokenEvent::Token { .. }) => n += 1,
            Some(TokenEvent::Done { .. }) => break,
            None => std::thread::yield_now(),
        }
    }
    assert!(n <= 3);
    server.shutdown().unwrap();
}

/// Regression for the old front-end's slot-exhaustion unfairness: the
/// legacy loop re-queued the head at the front but still burned an
/// admission slot per decode span, so later requests could overtake
/// earlier ones. The unified admission is FCFS: under sustained
/// backpressure (more requests than concurrent slots), first tokens must
/// appear in submission order.
#[test]
fn fcfs_admission_order_under_backpressure() {
    let mut c = cfg();
    c.max_batch = 2; // two concurrent slots: everything else queues
    let mut s = ServerCore::sim(c, 7).with_queue_depth(64);
    let handles: Vec<_> = (0..10)
        .map(|_| {
            s.submit(
                prompt(1500),
                SubmitOptions {
                    max_new_tokens: 12,
                    arrival: Some(0.0),
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    s.run_to_idle();
    let mut first_token_times = Vec::new();
    for h in handles {
        let events = h.collect_events();
        let first = events
            .iter()
            .find_map(|e| match e {
                TokenEvent::Token { at, .. } => Some(*at),
                TokenEvent::Done { .. } => None,
            })
            .expect("request must produce tokens");
        first_token_times.push(first);
    }
    // Submission order == id order; first tokens must be non-decreasing.
    for w in first_token_times.windows(2) {
        assert!(
            w[1] >= w[0],
            "FCFS violated: later submission started earlier ({} < {})",
            w[1],
            w[0]
        );
    }
    assert_eq!(s.engine().metrics.completed, 10);
}

#[test]
fn queue_full_is_backpressure_not_loss() {
    let mut s = ServerCore::sim(cfg(), 3).with_queue_depth(3);
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for _ in 0..8 {
        match s.submit(
            prompt(256),
            SubmitOptions {
                max_new_tokens: 4,
                ..Default::default()
            },
        ) {
            Ok(h) => accepted.push(h),
            Err(SubmitError::QueueFull { depth }) => {
                assert_eq!(depth, 3);
                rejected += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(rejected > 0, "depth 3 must reject some of 8 submissions");
    s.run_to_idle();
    // Every accepted request completes; nothing is silently lost.
    assert_eq!(s.engine().metrics.completed, accepted.len() as u64);
    for h in accepted {
        assert_eq!(h.collect().len(), 4);
    }
}

#[test]
fn shutdown_drains_queued_work() {
    let server = Server::start_sim(cfg(), 5).unwrap();
    let handles: Vec<_> = (0..9)
        .map(|_| {
            server
                .submit(
                    prompt(4000),
                    SubmitOptions {
                        max_new_tokens: 7,
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    // Immediate shutdown: graceful drain must finish all 9 first.
    let report = server.shutdown().unwrap();
    assert_eq!(report.completed, 9);
    for h in handles {
        let events = h.collect_events();
        assert_eq!(
            events.last(),
            Some(&TokenEvent::Done {
                reason: FinishReason::Completed
            })
        );
        assert_eq!(events.len(), 8, "7 tokens + Done");
    }
}

#[test]
fn cancel_mid_stream_stops_generation() {
    let mut s = ServerCore::sim(cfg(), 2);
    let long = s
        .submit(
            prompt(1024),
            SubmitOptions {
                max_new_tokens: 10_000,
                ..Default::default()
            },
        )
        .unwrap();
    let short = s
        .submit(
            prompt(1024),
            SubmitOptions {
                max_new_tokens: 6,
                ..Default::default()
            },
        )
        .unwrap();
    // Let both get going, then cancel the long one mid-decode.
    for _ in 0..12 {
        s.step();
    }
    assert!(s.cancel(long.id()));
    s.run_to_idle();
    let long_events = long.collect_events();
    assert_eq!(
        long_events.last(),
        Some(&TokenEvent::Done {
            reason: FinishReason::Cancelled
        })
    );
    assert!(long_events.len() < 10_001, "cancel must stop the stream early");
    assert_eq!(short.collect().len(), 6);
    assert_eq!(s.engine().metrics.completed, 1);
    s.engine().check_invariants().unwrap();
}

/// The unification property: for the same trace and seed, the serving
/// path (ServerCore over the sim backend) and `SimEngine` produce
/// identical token counts and TTFT/TBT metrics — one request lifecycle,
/// two entry points.
#[test]
fn server_path_matches_sim_engine_metrics() {
    check(6, |g| {
        let n = g.usize_range(8, 24);
        let isl = g.u64_range(64, 6000);
        let osl = g.u64_range(2, 48);
        let qps = g.f64_range(1.0, 12.0);
        let seed = g.case_seed;
        let w = jittered_workload(n, isl, osl, 0.3, qps, seed).sorted_by_arrival();

        let mut sim = engine_for(cfg(), seed);
        let sim_rep = sim.run(w.clone());

        let mut srv = ServerCore::sim(cfg(), seed).with_queue_depth(usize::MAX);
        let handles: Vec<_> = w
            .requests
            .iter()
            .map(|r| {
                srv.submit(
                    prompt(r.prompt_len as usize),
                    SubmitOptions {
                        max_new_tokens: r.output_len,
                        arrival: Some(r.arrival),
                        ..Default::default()
                    },
                )
                .expect("unbounded queue")
            })
            .collect();
        srv.run_to_idle();
        srv.engine().check_invariants()?;
        let streamed: usize = handles.into_iter().map(|h| h.collect().len()).sum();
        let srv_rep = srv.finish();

        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()));
        if srv_rep.completed != sim_rep.completed {
            return Err(format!(
                "completed {} != sim {}",
                srv_rep.completed, sim_rep.completed
            ));
        }
        if streamed as u64 != sim.metrics.output_tokens {
            return Err(format!(
                "streamed tokens {streamed} != sim output {}",
                sim.metrics.output_tokens
            ));
        }
        if !close(srv_rep.ttft.mean, sim_rep.ttft.mean) {
            return Err(format!(
                "ttft {} != sim {}",
                srv_rep.ttft.mean, sim_rep.ttft.mean
            ));
        }
        if !close(srv_rep.tbt.mean, sim_rep.tbt.mean) {
            return Err(format!(
                "tbt {} != sim {}",
                srv_rep.tbt.mean, sim_rep.tbt.mean
            ));
        }
        if !close(srv_rep.duration, sim_rep.duration) {
            return Err(format!(
                "duration {} != sim {}",
                srv_rep.duration, sim_rep.duration
            ));
        }
        Ok(())
    });
}

/// The cluster extension of the unification property: a cluster-backed
/// `ServerCore` (live submissions routed across N sim workers through
/// the `Router` seam) produces identical metrics to the batch
/// `ClusterEngine::run` for the same trace, seed, router and topology —
/// one cluster event loop, entered two ways.
#[test]
fn cluster_server_matches_cluster_engine_metrics() {
    check(6, |g| {
        let n = g.usize_range(8, 24);
        let isl = g.u64_range(64, 6000);
        let osl = g.u64_range(2, 48);
        let qps = g.f64_range(1.0, 12.0);
        let replicas = g.u64_range(2, 4) as u32;
        let routers = ["round-robin", "least-outstanding", "kv-pressure"];
        let router = *g.choose(&routers);
        let seed = g.case_seed;
        let label = format!("{replicas}x/{router}");
        let w = jittered_workload(n, isl, osl, 0.3, qps, seed).sorted_by_arrival();

        let mut batch = ClusterEngine::replicated(
            cfg(),
            replicas,
            seed,
            router_by_name(router).expect("known router"),
        );
        let batch_rep = batch.run(w.clone());
        let batch_tokens = batch.metrics.output_tokens;

        let mut srv = ServerCore::sim_replicated(
            cfg(),
            replicas,
            seed,
            router_by_name(router).expect("known router"),
        )
        .with_queue_depth(usize::MAX);
        let handles: Vec<_> = w
            .requests
            .iter()
            .map(|r| {
                srv.submit(
                    prompt(r.prompt_len as usize),
                    SubmitOptions {
                        max_new_tokens: r.output_len,
                        arrival: Some(r.arrival),
                        ..Default::default()
                    },
                )
                .expect("unbounded queue")
            })
            .collect();
        srv.run_to_idle();
        let streamed: usize = handles.into_iter().map(|h| h.collect().len()).sum();
        let srv_rep = srv.finish();

        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()));
        if srv_rep.completed != batch_rep.completed {
            return Err(format!(
                "{label}: completed {} != batch {}",
                srv_rep.completed, batch_rep.completed
            ));
        }
        if srv_rep.iterations != batch_rep.iterations {
            return Err(format!(
                "{label}: iterations {} != batch {}",
                srv_rep.iterations, batch_rep.iterations
            ));
        }
        if streamed as u64 != batch_tokens {
            return Err(format!(
                "{label}: streamed tokens {streamed} != batch output {batch_tokens}"
            ));
        }
        if !close(srv_rep.ttft.mean, batch_rep.ttft.mean) {
            return Err(format!(
                "{label}: ttft {} != batch {}",
                srv_rep.ttft.mean, batch_rep.ttft.mean
            ));
        }
        if !close(srv_rep.tbt.mean, batch_rep.tbt.mean) {
            return Err(format!(
                "{label}: tbt {} != batch {}",
                srv_rep.tbt.mean, batch_rep.tbt.mean
            ));
        }
        if !close(srv_rep.duration, batch_rep.duration) {
            return Err(format!(
                "{label}: duration {} != batch {}",
                srv_rep.duration, batch_rep.duration
            ));
        }
        Ok(())
    });
}

/// Live multi-worker serving keeps the whole request lifecycle:
/// backpressure at the configured depth, cancel before admission, token
/// streams from every worker, and one merged drain report.
#[test]
fn cluster_server_backpressure_cancel_and_merged_drain() {
    let mut s = ServerCore::sim_replicated(
        cfg(),
        2,
        1,
        router_by_name("least-outstanding").unwrap(),
    )
    .with_queue_depth(4);
    let handles: Vec<_> = (0..4)
        .map(|_| {
            s.submit(
                prompt(2048),
                SubmitOptions {
                    max_new_tokens: 8,
                    arrival: Some(0.0),
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    assert_eq!(
        s.submit(prompt(16), SubmitOptions::default()).unwrap_err(),
        SubmitError::QueueFull { depth: 4 }
    );
    // Cancel the last submission while still queued.
    let cancelled_id = handles[3].id();
    assert!(s.cancel(cancelled_id));
    assert!(!s.cancel(cancelled_id), "double cancel reports unknown");
    s.run_to_idle();
    // Both workers served traffic (live routing, not static sharding).
    for (i, w) in s.cluster().workers.iter().enumerate() {
        assert!(
            w.core.metrics.completed > 0,
            "worker {i} never completed a request"
        );
    }
    assert_eq!(s.cancelled, 1);
    let rep = s.finish();
    assert_eq!(rep.completed, 3);
    assert!(
        rep.system.starts_with("server/") && rep.system.contains("x2"),
        "merged report must carry the cluster label: {}",
        rep.system
    );
    for (i, h) in handles.into_iter().enumerate() {
        let events = h.collect_events();
        if i == 3 {
            assert_eq!(
                events.last(),
                Some(&TokenEvent::Done {
                    reason: FinishReason::Cancelled
                })
            );
        } else {
            assert_eq!(events.len(), 9, "8 tokens + Done");
            assert_eq!(
                events.last(),
                Some(&TokenEvent::Done {
                    reason: FinishReason::Completed
                })
            );
        }
    }
}

/// A disaggregated prefill/decode fleet serves live traffic through the
/// same front-end: first tokens come off the prefill workers, the rest
/// stream from decode workers after the KV transfer, and the drain
/// report is the merged Dynamo-style system report.
#[test]
fn disagg_cluster_serves_live_streams() {
    let mut s = ServerCore::sim_disagg(
        cfg(),
        1,
        1,
        1,
        router_by_name("least-outstanding").unwrap(),
    );
    let handles: Vec<_> = (0..6)
        .map(|i| {
            s.submit(
                prompt(3000),
                SubmitOptions {
                    max_new_tokens: 12,
                    arrival: Some(i as f64 * 0.4),
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    s.run_to_idle();
    // The decode worker (index 1) must have served the transferred KV.
    assert!(s.cluster().workers[1].core.metrics.iterations > 0);
    let rep = s.finish();
    assert_eq!(rep.completed, 6);
    assert!(rep.system.contains("1P1D"), "got {}", rep.system);
    for h in handles {
        let events = h.collect_events();
        assert_eq!(events.len(), 13, "12 tokens + Done");
        let times: Vec<f64> = events
            .iter()
            .filter_map(|e| match e {
                TokenEvent::Token { at, .. } => Some(*at),
                TokenEvent::Done { .. } => None,
            })
            .collect();
        assert!(times.windows(2).all(|w| w[1] >= w[0]), "timestamps monotone");
    }
}

/// The threaded transport serves a routed cluster transparently: spawn,
/// submit from client threads, stream, drain on shutdown.
#[test]
fn threaded_cluster_server_drains_on_shutdown() {
    let server = Server::start_sim_replicated(cfg(), 3, 2, "kv-pressure").unwrap();
    let handles: Vec<_> = (0..9)
        .map(|i| {
            server
                .submit(
                    prompt(512 + 256 * (i % 3)),
                    SubmitOptions {
                        max_new_tokens: 6,
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    let report = server.shutdown().unwrap();
    assert_eq!(report.completed, 9);
    assert!(report.system.contains("x3"), "got {}", report.system);
    for h in handles {
        assert_eq!(h.collect().len(), 6);
    }
}

/// DuetScheduler drives the serving path too (acceptance criterion: any
/// scheduler can be selected for serving).
#[test]
fn duet_scheduler_serves_through_front_end() {
    let duet = ServingConfig::default_8b().with_policy(Policy::Duet);
    let mut s = ServerCore::sim(duet, 2);
    let handles: Vec<_> = (0..20)
        .map(|i| {
            s.submit(
                prompt(8000),
                SubmitOptions {
                    max_new_tokens: 32,
                    arrival: Some(i as f64 * 0.12),
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    s.run_to_idle();
    for h in handles {
        assert_eq!(h.collect().len(), 32);
    }
    assert_eq!(s.engine().metrics.completed, 20);
    assert!(
        s.engine().metrics.spatial_iterations > 0,
        "duet should multiplex under prefill pressure on the serving path"
    );
    s.engine().check_invariants().unwrap();
}
