//! Unified front-end integration: token streaming, FCFS admission
//! fairness under backpressure, cancel/drain semantics, and the identity
//! property — the server path and `SimEngine` produce the same metrics
//! for the same workload and seed. Everything runs on the simulated
//! execution backend, so none of these tests require AOT artifacts.

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::engine_for;
use duetserve::server::{
    FinishReason, Server, ServerCore, SubmitError, SubmitOptions, TokenEvent,
};
use duetserve::util::proptest::check;
use duetserve::workload::synthetic::jittered_workload;

fn cfg() -> ServingConfig {
    ServingConfig::default_8b().with_policy(Policy::VllmChunked)
}

fn prompt(n: usize) -> Vec<i32> {
    (0..n).map(|i| (i % 997) as i32).collect()
}

#[test]
fn streams_tokens_and_terminates() {
    let server = Server::start_sim(cfg(), 4).unwrap();
    let handle = server
        .submit(
            vec![5, 99, 1023, 7, 300, 12],
            SubmitOptions {
                max_new_tokens: 6,
                ..Default::default()
            },
        )
        .unwrap();
    let toks = handle.collect();
    assert_eq!(toks.len(), 6);
    server.shutdown().unwrap();
}

#[test]
fn concurrent_submissions_all_complete() {
    let server = Server::start_sim(cfg(), 4).unwrap();
    let handles: Vec<_> = (0..12)
        .map(|i| {
            server
                .submit(
                    prompt(64 + i * 31),
                    SubmitOptions {
                        max_new_tokens: 5,
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    for h in handles {
        assert_eq!(h.collect().len(), 5);
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.completed, 12);
}

#[test]
fn try_next_is_nonblocking() {
    let server = Server::start_sim(cfg(), 1).unwrap();
    let handle = server
        .submit(
            vec![1, 2, 3],
            SubmitOptions {
                max_new_tokens: 3,
                ..Default::default()
            },
        )
        .unwrap();
    // Either nothing yet or an event — must not hang.
    let _ = handle.try_next();
    let mut n = 0;
    loop {
        match handle.try_next() {
            Some(TokenEvent::Token { .. }) => n += 1,
            Some(TokenEvent::Done { .. }) => break,
            None => std::thread::yield_now(),
        }
    }
    assert!(n <= 3);
    server.shutdown().unwrap();
}

/// Regression for the old front-end's slot-exhaustion unfairness: the
/// legacy loop re-queued the head at the front but still burned an
/// admission slot per decode span, so later requests could overtake
/// earlier ones. The unified admission is FCFS: under sustained
/// backpressure (more requests than concurrent slots), first tokens must
/// appear in submission order.
#[test]
fn fcfs_admission_order_under_backpressure() {
    let mut c = cfg();
    c.max_batch = 2; // two concurrent slots: everything else queues
    let mut s = ServerCore::sim(c, 7).with_queue_depth(64);
    let handles: Vec<_> = (0..10)
        .map(|_| {
            s.submit(
                prompt(1500),
                SubmitOptions {
                    max_new_tokens: 12,
                    arrival: Some(0.0),
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    s.run_to_idle();
    let mut first_token_times = Vec::new();
    for h in handles {
        let events = h.collect_events();
        let first = events
            .iter()
            .find_map(|e| match e {
                TokenEvent::Token { at, .. } => Some(*at),
                TokenEvent::Done { .. } => None,
            })
            .expect("request must produce tokens");
        first_token_times.push(first);
    }
    // Submission order == id order; first tokens must be non-decreasing.
    for w in first_token_times.windows(2) {
        assert!(
            w[1] >= w[0],
            "FCFS violated: later submission started earlier ({} < {})",
            w[1],
            w[0]
        );
    }
    assert_eq!(s.engine().metrics.completed, 10);
}

#[test]
fn queue_full_is_backpressure_not_loss() {
    let mut s = ServerCore::sim(cfg(), 3).with_queue_depth(3);
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for _ in 0..8 {
        match s.submit(
            prompt(256),
            SubmitOptions {
                max_new_tokens: 4,
                ..Default::default()
            },
        ) {
            Ok(h) => accepted.push(h),
            Err(SubmitError::QueueFull { depth }) => {
                assert_eq!(depth, 3);
                rejected += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(rejected > 0, "depth 3 must reject some of 8 submissions");
    s.run_to_idle();
    // Every accepted request completes; nothing is silently lost.
    assert_eq!(s.engine().metrics.completed, accepted.len() as u64);
    for h in accepted {
        assert_eq!(h.collect().len(), 4);
    }
}

#[test]
fn shutdown_drains_queued_work() {
    let server = Server::start_sim(cfg(), 5).unwrap();
    let handles: Vec<_> = (0..9)
        .map(|_| {
            server
                .submit(
                    prompt(4000),
                    SubmitOptions {
                        max_new_tokens: 7,
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    // Immediate shutdown: graceful drain must finish all 9 first.
    let report = server.shutdown().unwrap();
    assert_eq!(report.completed, 9);
    for h in handles {
        let events = h.collect_events();
        assert_eq!(
            events.last(),
            Some(&TokenEvent::Done {
                reason: FinishReason::Completed
            })
        );
        assert_eq!(events.len(), 8, "7 tokens + Done");
    }
}

#[test]
fn cancel_mid_stream_stops_generation() {
    let mut s = ServerCore::sim(cfg(), 2);
    let long = s
        .submit(
            prompt(1024),
            SubmitOptions {
                max_new_tokens: 10_000,
                ..Default::default()
            },
        )
        .unwrap();
    let short = s
        .submit(
            prompt(1024),
            SubmitOptions {
                max_new_tokens: 6,
                ..Default::default()
            },
        )
        .unwrap();
    // Let both get going, then cancel the long one mid-decode.
    for _ in 0..12 {
        s.step();
    }
    assert!(s.cancel(long.id()));
    s.run_to_idle();
    let long_events = long.collect_events();
    assert_eq!(
        long_events.last(),
        Some(&TokenEvent::Done {
            reason: FinishReason::Cancelled
        })
    );
    assert!(long_events.len() < 10_001, "cancel must stop the stream early");
    assert_eq!(short.collect().len(), 6);
    assert_eq!(s.engine().metrics.completed, 1);
    s.engine().check_invariants().unwrap();
}

/// The unification property: for the same trace and seed, the serving
/// path (ServerCore over the sim backend) and `SimEngine` produce
/// identical token counts and TTFT/TBT metrics — one request lifecycle,
/// two entry points.
#[test]
fn server_path_matches_sim_engine_metrics() {
    check(6, |g| {
        let n = g.usize_range(8, 24);
        let isl = g.u64_range(64, 6000);
        let osl = g.u64_range(2, 48);
        let qps = g.f64_range(1.0, 12.0);
        let seed = g.case_seed;
        let w = jittered_workload(n, isl, osl, 0.3, qps, seed).sorted_by_arrival();

        let mut sim = engine_for(cfg(), seed);
        let sim_rep = sim.run(w.clone());

        let mut srv = ServerCore::sim(cfg(), seed).with_queue_depth(usize::MAX);
        let handles: Vec<_> = w
            .requests
            .iter()
            .map(|r| {
                srv.submit(
                    prompt(r.prompt_len as usize),
                    SubmitOptions {
                        max_new_tokens: r.output_len,
                        arrival: Some(r.arrival),
                        ..Default::default()
                    },
                )
                .expect("unbounded queue")
            })
            .collect();
        srv.run_to_idle();
        srv.engine().check_invariants()?;
        let streamed: usize = handles.into_iter().map(|h| h.collect().len()).sum();
        let srv_rep = srv.finish();

        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()));
        if srv_rep.completed != sim_rep.completed {
            return Err(format!(
                "completed {} != sim {}",
                srv_rep.completed, sim_rep.completed
            ));
        }
        if streamed as u64 != sim.metrics.output_tokens {
            return Err(format!(
                "streamed tokens {streamed} != sim output {}",
                sim.metrics.output_tokens
            ));
        }
        if !close(srv_rep.ttft.mean, sim_rep.ttft.mean) {
            return Err(format!(
                "ttft {} != sim {}",
                srv_rep.ttft.mean, sim_rep.ttft.mean
            ));
        }
        if !close(srv_rep.tbt.mean, sim_rep.tbt.mean) {
            return Err(format!(
                "tbt {} != sim {}",
                srv_rep.tbt.mean, sim_rep.tbt.mean
            ));
        }
        if !close(srv_rep.duration, sim_rep.duration) {
            return Err(format!(
                "duration {} != sim {}",
                srv_rep.duration, sim_rep.duration
            ));
        }
        Ok(())
    });
}

/// DuetScheduler drives the serving path too (acceptance criterion: any
/// scheduler can be selected for serving).
#[test]
fn duet_scheduler_serves_through_front_end() {
    let duet = ServingConfig::default_8b().with_policy(Policy::Duet);
    let mut s = ServerCore::sim(duet, 2);
    let handles: Vec<_> = (0..20)
        .map(|i| {
            s.submit(
                prompt(8000),
                SubmitOptions {
                    max_new_tokens: 32,
                    arrival: Some(i as f64 * 0.12),
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    s.run_to_idle();
    for h in handles {
        assert_eq!(h.collect().len(), 32);
    }
    assert_eq!(s.engine().metrics.completed, 20);
    assert!(
        s.engine().metrics.spatial_iterations > 0,
        "duet should multiplex under prefill pressure on the serving path"
    );
    s.engine().check_invariants().unwrap();
}
