//! Threaded front-end integration: token streaming, concurrency, clean
//! shutdown, and schedule-invariance of greedy outputs through the
//! server path. Skips when artifacts are absent.

use duetserve::runtime::{artifacts, TinyRuntime};
use duetserve::server::{Server, TokenEvent};

fn available() -> bool {
    artifacts::artifacts_available()
}

#[test]
fn streams_tokens_and_terminates() {
    if !available() {
        return;
    }
    let server = Server::start(TinyRuntime::load_default, 4);
    let stream = server.submit(vec![5, 99, 1023, 7, 300, 12], 6);
    let toks = stream.collect();
    assert_eq!(toks.len(), 6);
    server.shutdown().unwrap();
}

#[test]
fn server_tokens_match_direct_runtime() {
    if !available() {
        return;
    }
    let prompt = vec![11i32, 500, 42, 1999, 8];
    // Direct greedy path.
    let mut rt = TinyRuntime::load_default().unwrap();
    let pre = rt.prefill(&prompt).unwrap();
    rt.install_slot(0, prompt.len(), &pre.k, &pre.v);
    let mut direct = vec![pre.next_token];
    let mut tokens = [0i32; 8];
    let mut lengths = [0i32; 8];
    tokens[0] = pre.next_token;
    lengths[0] = prompt.len() as i32;
    for _ in 0..3 {
        let next = rt.decode_step(&tokens, &lengths).unwrap();
        direct.push(next[0]);
        tokens[0] = next[0];
        lengths[0] += 1;
    }
    drop(rt);

    let server = Server::start(TinyRuntime::load_default, 2);
    let toks = server.submit(prompt, 4).collect();
    assert_eq!(toks, direct, "server path must match direct greedy decode");
    server.shutdown().unwrap();
}

#[test]
fn concurrent_submissions_all_complete() {
    if !available() {
        return;
    }
    let server = Server::start(TinyRuntime::load_default, 4);
    let streams: Vec<_> = (0..12)
        .map(|i| {
            server.submit(
                (0..6 + i % 5).map(|j| ((i * 53 + j * 19) % 2048) as i32).collect(),
                5,
            )
        })
        .collect();
    for s in streams {
        assert_eq!(s.collect().len(), 5);
    }
    server.shutdown().unwrap();
}

#[test]
fn try_next_is_nonblocking() {
    if !available() {
        return;
    }
    let server = Server::start(TinyRuntime::load_default, 1);
    let stream = server.submit(vec![1, 2, 3], 3);
    // Either nothing yet or a token — must not hang.
    let _ = stream.try_next();
    let mut n = 0;
    loop {
        match stream.try_next() {
            Some(TokenEvent::Token(_)) => n += 1,
            Some(TokenEvent::Done) => break,
            None => std::thread::yield_now(),
        }
    }
    assert!(n <= 3);
    server.shutdown().unwrap();
}
