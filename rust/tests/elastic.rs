//! Elastic role-planner integration properties.
//!
//! Three guarantees the planner must not break:
//!
//! 1. **Off means off, byte-for-byte.** `--planner off` (the default)
//!    must reproduce the legacy fixed-role trajectory exactly — same
//!    worker clocks to the bit, same report — so every existing
//!    live ≡ batch-replay property keeps holding with the planner
//!    compiled in.
//! 2. **`static` is the old `reconfigurable: true`,** under a new name:
//!    the explicit mode and the legacy flag must produce identical runs,
//!    including the same (nonzero) reconfiguration count.
//! 3. **Flips are safe under churn.** Hysteresis bounds the flip count
//!    under oscillating burst load, and cancelling requests mid-run —
//!    including ones mid-KV-transfer while workers re-role around them —
//!    must leave every incremental invariant intact and the accounting
//!    exact.

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{
    router_by_name, ClusterEngine, PlannerMode, ServingTopology, TopologyStep,
};
use duetserve::workload::synthetic::{burst_mix_workload, fixed_workload, BurstProfile};
use duetserve::workload::Workload;

/// Cap on events so a livelock fails loudly instead of hanging.
const MAX_EVENTS: u64 = 2_000_000;

/// Drive a cluster live: inject everything, step to exhaustion, drain.
fn run_live(cluster: &mut ClusterEngine, w: Workload) -> duetserve::metrics::Report {
    for r in w.requests {
        cluster.inject(r);
    }
    let mut events = 0u64;
    loop {
        match cluster.step_next(None) {
            TopologyStep::Exhausted => break,
            TopologyStep::Diverged(e) => panic!("cluster diverged: {e}"),
            _ => {
                events += 1;
                assert!(events < MAX_EVENTS, "event cap hit — livelock?");
            }
        }
    }
    cluster.drain()
}

#[test]
fn planner_off_is_byte_identical_to_legacy_fleet() {
    let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    let w = fixed_workload(60, 4000, 32, 8.0, 17);

    // Legacy cluster: planner never mentioned.
    let mut legacy = ClusterEngine::replicated(
        cfg.clone(),
        3,
        9,
        router_by_name("round-robin").unwrap(),
    );
    let rep_legacy = run_live(&mut legacy, w.clone());

    // Planner explicitly off, with a planner interval configured: mode
    // off must make the interval inert.
    let mut off = ClusterEngine::replicated(cfg, 3, 9, router_by_name("round-robin").unwrap());
    off.set_planner(PlannerMode::Off);
    off.set_planner_interval(5.0);
    let rep_off = run_live(&mut off, w);

    assert_eq!(rep_legacy.completed, 60);
    assert_eq!(rep_off.completed, rep_legacy.completed);
    assert_eq!(rep_off.iterations, rep_legacy.iterations);
    assert_eq!(
        rep_off.duration.to_bits(),
        rep_legacy.duration.to_bits(),
        "planner-off duration diverged from the legacy trajectory"
    );
    assert_eq!(rep_off.reconfigs, 0);
    assert_eq!(rep_legacy.reconfigs, 0);
    for (i, (a, b)) in legacy.workers.iter().zip(off.workers.iter()).enumerate() {
        assert_eq!(
            a.core.clock.to_bits(),
            b.core.clock.to_bits(),
            "worker {i} clock diverged with the planner off"
        );
    }
}

#[test]
fn static_mode_is_the_reconfigurable_flag_by_another_name() {
    let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
        prefill_gpus: 2,
        decode_gpus: 2,
    });
    let w = fixed_workload(300, 12_000, 8, 12.0, 4);

    let mut flagged =
        ClusterEngine::disagg(cfg.clone(), 2, 2, 7, router_by_name("least-outstanding").unwrap());
    flagged.reconfigurable = true;
    flagged.set_planner_interval(10.0);
    let rep_flag = flagged.run(w.clone());

    let mut explicit =
        ClusterEngine::disagg(cfg, 2, 2, 7, router_by_name("least-outstanding").unwrap());
    explicit.set_planner(PlannerMode::Static);
    explicit.set_planner_interval(10.0);
    let rep_mode = explicit.run(w);

    assert_eq!(rep_flag.completed, 300);
    assert_eq!(rep_mode.completed, rep_flag.completed);
    assert_eq!(rep_mode.iterations, rep_flag.iterations);
    assert_eq!(rep_mode.duration.to_bits(), rep_flag.duration.to_bits());
    assert_eq!(rep_mode.reconfigs, rep_flag.reconfigs);
    assert!(
        rep_mode.reconfigs > 0,
        "the static planner never fired under the 12k-token flood"
    );
}

#[test]
fn hysteresis_bounds_flips_under_oscillating_load() {
    let mut cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    cfg.tbt_slo = 0.04;
    let p = BurstProfile::default();
    let w = burst_mix_workload(&p, 21);
    let total = w.requests.len() as u64;

    let mut cluster =
        ClusterEngine::replicated(cfg, 4, 3, router_by_name("conditional").unwrap());
    cluster.reconfig_s = 1.0;
    cluster.set_planner(PlannerMode::Elastic);
    cluster.set_planner_interval(2.0);
    let rep = cluster.run(w);

    assert_eq!(rep.completed, total);
    cluster.check_invariants().expect("invariants after run");
    // The burst windows oscillate every 120 s; a thrashing planner at a
    // 2 s cadence could re-role on every tick. The dwell gate allows at
    // most one committed decision per 45 s window (plus the initial
    // flip), and a decision re-roles at most all four workers.
    let decisions = 2 + (rep.duration / 45.0) as u64;
    assert!(
        rep.reconfigs <= 4 * decisions,
        "{} worker flips over {:.0}s smells like thrash (allowed {})",
        rep.reconfigs,
        rep.duration,
        4 * decisions
    );
}

#[test]
fn mid_run_cancels_survive_flips_and_transfers() {
    let mut cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    cfg.tbt_slo = 0.04;
    let w = fixed_workload(60, 12_000, 8, 12.0, 4);

    let mut cluster =
        ClusterEngine::replicated(cfg, 4, 11, router_by_name("conditional").unwrap());
    cluster.reconfig_s = 1.0;
    cluster.set_planner(PlannerMode::Elastic);
    cluster.set_planner_interval(5.0);

    for r in w.requests {
        cluster.inject(r);
    }
    // Step partway in so some requests are queued, some running, and —
    // on a split fleet — some mid-KV-transfer.
    let mut events = 0u64;
    for _ in 0..400 {
        match cluster.step_next(None) {
            TopologyStep::Exhausted => break,
            TopologyStep::Diverged(e) => panic!("cluster diverged early: {e}"),
            _ => events += 1,
        }
    }
    assert!(events > 0, "no events before the cancel wave");
    // Cancel every 7th request at whatever stage it reached.
    let mut removed = 0u64;
    for id in (0..60).step_by(7) {
        if cluster.cancel(id) {
            removed += 1;
        }
    }
    assert!(removed > 0, "the cancel wave removed nothing");
    cluster
        .check_invariants()
        .expect("invariants right after the cancel wave");
    loop {
        match cluster.step_next(None) {
            TopologyStep::Exhausted => break,
            TopologyStep::Diverged(e) => panic!("cluster diverged after cancels: {e}"),
            _ => {
                events += 1;
                assert!(events < MAX_EVENTS, "event cap hit — livelock?");
            }
        }
    }
    let rep = cluster.drain();
    cluster.check_invariants().expect("invariants after drain");
    assert_eq!(
        rep.completed,
        60 - removed,
        "cancelled requests must be exactly the ones missing from the drain"
    );
    assert!(
        cluster.reconfigs > 0,
        "the 12k-token flood never triggered a re-role — the test lost its point"
    );
}
