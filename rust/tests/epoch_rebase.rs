//! Engine-clock epoch regression tests: a trace spanning one or more
//! idle re-bases must produce the identical merged `Report` as the same
//! trace served inside a single epoch (modulo the epoch counters), over
//! both a single `EngineCore` and a 2-worker `ClusterEngine` — and the
//! divergence guard must genuinely re-arm, so cumulative engine time can
//! run past the per-epoch horizon with zero drops.

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{router_by_name, REBASE_FRACTION};
use duetserve::metrics::Report;
use duetserve::server::{FinishReason, RequestHandle, ServerCore, SubmitOptions, TokenEvent};

fn cfg(max_engine_time: f64) -> ServingConfig {
    let mut c = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    c.max_engine_time = max_engine_time;
    c
}

fn prompt(n: usize) -> Vec<i32> {
    (0..n).map(|i| (i % 811) as i32).collect()
}

/// Bursts of (arrival, prompt_len, max_new_tokens) separated by idle
/// gaps long enough to cross the re-base threshold when the horizon is
/// small.
fn bursts() -> Vec<Vec<(f64, usize, u64)>> {
    (0..3)
        .map(|b| {
            let t0 = b as f64 * 30.0;
            (0..3).map(|i| (t0, 512 + i * 64, 8)).collect()
        })
        .collect()
}

/// Feed the bursts through a `ServerCore` the live way (submit a burst,
/// drain it, submit the next — the pattern under which the engine goes
/// fully idle between bursts), then return every stream's events plus
/// the final report.
fn serve_bursts(mut s: ServerCore) -> (Vec<Vec<TokenEvent>>, Report) {
    let mut handles: Vec<RequestHandle> = Vec::new();
    for burst in bursts() {
        for (arrival, isl, osl) in burst {
            let h = s
                .submit(
                    prompt(isl),
                    SubmitOptions {
                        max_new_tokens: osl,
                        arrival: Some(arrival),
                        ..Default::default()
                    },
                )
                .expect("submission within the epoch horizon");
            handles.push(h);
        }
        s.run_to_idle();
    }
    let rep = s.finish();
    let events = handles.into_iter().map(|h| h.collect_events()).collect();
    (events, rep)
}

fn token_times(events: &[TokenEvent]) -> Vec<f64> {
    events
        .iter()
        .filter_map(|e| match e {
            TokenEvent::Token { at, .. } => Some(*at),
            TokenEvent::Done { .. } => None,
        })
        .collect()
}

fn assert_reports_match(multi: &Report, single: &Report) {
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * (1.0 + a.abs().max(b.abs()));
    assert_eq!(multi.completed, single.completed, "completed");
    assert_eq!(multi.iterations, single.iterations, "iterations");
    assert!(
        close(multi.duration, single.duration),
        "duration {} != {}",
        multi.duration,
        single.duration
    );
    assert!(
        close(multi.ttft.mean, single.ttft.mean),
        "ttft {} != {}",
        multi.ttft.mean,
        single.ttft.mean
    );
    assert!(
        close(multi.tbt.mean, single.tbt.mean),
        "tbt {} != {}",
        multi.tbt.mean,
        single.tbt.mean
    );
    assert!(
        close(multi.engine_uptime_s, single.engine_uptime_s),
        "uptime {} != {}",
        multi.engine_uptime_s,
        single.engine_uptime_s
    );
}

/// Single `EngineCore`: a small horizon forces a re-base in each
/// inter-burst idle gap; the merged report must match the same trace
/// served in one epoch under the default horizon, and the absolute
/// (epoch-offset-re-based) SSE `at` stamps must match too.
#[test]
fn engine_core_report_identical_across_epoch_rebase() {
    // Horizon 40 ⇒ re-base threshold 20 < the 30 s burst spacing.
    let (ev_multi, rep_multi) = serve_bursts(ServerCore::sim(cfg(40.0), 7));
    let (ev_single, rep_single) = serve_bursts(ServerCore::sim(cfg(3.0e4), 7));

    assert!(
        rep_multi.engine_epoch >= 2,
        "idle-separated bursts must re-base: epoch {}",
        rep_multi.engine_epoch
    );
    assert_eq!(rep_single.engine_epoch, 0, "default horizon never re-bases");
    assert_reports_match(&rep_multi, &rep_single);

    // Token timestamps live on the absolute timeline in both runs:
    // monotone per stream, and equal across runs within float noise.
    assert_eq!(ev_multi.len(), ev_single.len());
    for (m, s) in ev_multi.iter().zip(&ev_single) {
        assert_eq!(
            m.last(),
            Some(&TokenEvent::Done {
                reason: FinishReason::Completed
            })
        );
        let (tm, ts) = (token_times(m), token_times(s));
        assert_eq!(tm.len(), ts.len());
        assert!(tm.windows(2).all(|w| w[1] >= w[0]), "at stamps monotone");
        for (a, b) in tm.iter().zip(&ts) {
            assert!((a - b).abs() <= 1e-6 * (1.0 + a.abs()), "at {a} != {b}");
        }
    }
}

/// 2-worker `ClusterEngine` behind the serving front-end: the cluster
/// re-bases all workers by a common delta, and the merged cross-epoch
/// drain report matches the single-epoch run.
#[test]
fn cluster_report_identical_across_epoch_rebase() {
    let mk = |horizon: f64| {
        ServerCore::sim_replicated(
            cfg(horizon),
            2,
            11,
            router_by_name("least-outstanding").expect("known router"),
        )
    };
    let (ev_multi, rep_multi) = serve_bursts(mk(40.0));
    let (ev_single, rep_single) = serve_bursts(mk(3.0e4));

    assert!(
        rep_multi.engine_epoch >= 2,
        "cluster must re-base between bursts: epoch {}",
        rep_multi.engine_epoch
    );
    assert_eq!(rep_single.engine_epoch, 0);
    assert_reports_match(&rep_multi, &rep_single);
    for (m, s) in ev_multi.iter().zip(&ev_single) {
        let (tm, ts) = (token_times(m), token_times(s));
        assert_eq!(tm.len(), ts.len());
        assert!(tm.windows(2).all(|w| w[1] >= w[0]));
        for (a, b) in tm.iter().zip(&ts) {
            assert!((a - b).abs() <= 1e-6 * (1.0 + a.abs()), "at {a} != {b}");
        }
    }
}

/// An accepted arrival must never trip the divergence guard by itself:
/// when the idle epoch sits *below* the threshold re-base point, a
/// submission near the `uptime + horizon` bound would previously make
/// the idle jump overshoot the horizon and drain itself — the serving
/// front-end now forces a re-base before any over-horizon jump.
#[test]
fn forced_rebase_absorbs_over_horizon_idle_jump() {
    let horizon = 10.0;
    let mut s = ServerCore::sim(cfg(horizon), 5);
    let first = s
        .submit(
            prompt(2048),
            SubmitOptions {
                max_new_tokens: 32,
                arrival: Some(0.0),
                ..Default::default()
            },
        )
        .unwrap();
    s.run_to_idle();
    let uptime = s.clock();
    assert!(
        uptime > 0.0 && uptime < REBASE_FRACTION * horizon,
        "scenario needs an epoch below the re-base threshold: {uptime}"
    );
    // Within the submit bound, but past the *current* epoch's remaining
    // horizon (local arrival > max_engine_time while offset is 0).
    let far = horizon + 0.5 * uptime;
    let second = s
        .submit(
            prompt(256),
            SubmitOptions {
                max_new_tokens: 4,
                arrival: Some(far),
                ..Default::default()
            },
        )
        .expect("within uptime + horizon");
    s.run_to_idle();
    assert_eq!(s.engine().dropped, 0, "over-horizon jump must not diverge");
    assert!(s.epoch() >= 1, "the jump must have forced a re-base");
    let rep = s.finish();
    assert_eq!(rep.completed, 2);
    for h in [first, second] {
        assert_eq!(
            h.collect_events().last(),
            Some(&TokenEvent::Done {
                reason: FinishReason::Completed
            })
        );
    }
}

/// The point of the whole exercise: with a tiny horizon, cumulative
/// engine time runs well past the old hard cliff while every request
/// still completes — the divergence guard re-arms per epoch instead of
/// dropping all traffic forever.
#[test]
fn divergence_guard_rearms_past_old_horizon() {
    let horizon = 10.0;
    let mut s = ServerCore::sim(cfg(horizon), 3);
    let mut handles = Vec::new();
    // Each burst sits just over half the horizon away from the previous
    // one, so every idle gap crosses the re-base threshold and total
    // engine time ends several horizons deep.
    for b in 0..4 {
        let arrival = b as f64 * 6.0;
        for _ in 0..2 {
            handles.push(
                s.submit(
                    prompt(256),
                    SubmitOptions {
                        max_new_tokens: 6,
                        arrival: Some(arrival),
                        ..Default::default()
                    },
                )
                .expect("arrival within the rolling epoch horizon"),
            );
        }
        s.run_to_idle();
    }
    assert_eq!(s.engine().dropped, 0, "no divergence drops");
    let rep = s.finish();
    assert_eq!(rep.completed, 8);
    assert!(
        rep.engine_uptime_s > horizon,
        "uptime {} must pass the per-epoch horizon {horizon}",
        rep.engine_uptime_s
    );
    assert!(rep.engine_epoch >= 2, "epoch {}", rep.engine_epoch);
    for h in handles {
        let ev = h.collect_events();
        assert_eq!(
            ev.last(),
            Some(&TokenEvent::Done {
                reason: FinishReason::Completed
            })
        );
    }
}
