//! Streaming-recorder properties: the quantile sketch answers within its
//! rank-error budget against `stats::percentile` on the exact vectors
//! (across adversarial distributions), sketch merges track concatenated
//! streams, and `Recorder::merge` in streaming mode preserves every
//! counted field — including the SLO-attainment counts — without keeping
//! per-sample history.

use duetserve::metrics::{QuantileSketch, Recorder, RecorderMode};
use duetserve::request::Request;
use duetserve::util::proptest::check;
use duetserve::util::stats;

/// Rank distance (as a fraction of n) between the sketch's answer and
/// the true order statistic: 0 when `got` actually occupies the target
/// rank in the sorted exact vector.
fn rank_error(sorted: &[f64], got: f64, q: f64) -> f64 {
    let n = sorted.len() as f64;
    let below = sorted.iter().filter(|&&x| x < got).count() as f64;
    let at_or_below = sorted.iter().filter(|&&x| x <= got).count() as f64;
    let target = (q * n).ceil().max(1.0);
    if target < below + 1.0 {
        (below + 1.0 - target) / n
    } else if target > at_or_below {
        (target - at_or_below) / n
    } else {
        0.0
    }
}

/// Adversarial sample streams: sorted, reverse-sorted, constant,
/// bimodal, heavy-tailed, and sawtooth.
fn adversarial_stream(kind: usize, n: usize, seed: u64) -> Vec<f64> {
    let mix = |i: usize| ((i as u64).wrapping_mul(2654435761).wrapping_add(seed) % 100_003) as f64;
    (0..n)
        .map(|i| match kind % 6 {
            // ascending / descending / constant
            0 => i as f64,
            1 => (n - i) as f64,
            2 => 42.125,
            // bimodal: tight cluster + far cluster
            3 => {
                if i % 7 == 0 {
                    1000.0 + mix(i) / 1e4
                } else {
                    1.0 + mix(i) / 1e6
                }
            }
            // heavy near zero
            4 => 1.0 / (1.0 + mix(i) / 100.0),
            // sawtooth + jitter
            _ => (i % 97) as f64 + mix(i) / 1e6,
        })
        .collect()
}

/// Single-stream accuracy: p50 and p99 within the sketch's rank-error
/// budget (ε = 0.005, asserted with 2ε slack for rank-convention skew).
#[test]
fn sketch_quantiles_within_rank_eps_of_exact() {
    check(12, |g| {
        let kind = g.usize_range(0, 5);
        let n = g.usize_range(2_000, 30_000);
        let values = adversarial_stream(kind, n, g.case_seed);
        let mut sk = QuantileSketch::default();
        for &v in &values {
            sk.insert(v);
        }
        let mut sorted = values.clone();
        sorted.sort_by(f64::total_cmp);
        for &q in &[0.5, 0.9, 0.99] {
            let got = sk.quantile(q);
            let err = rank_error(&sorted, got, q);
            if err > 0.015 {
                return Err(format!(
                    "kind {kind} n {n} q {q}: rank error {err:.4} (got {got}, exact {})",
                    stats::percentile_sorted(&sorted, q * 100.0)
                ));
            }
        }
        Ok(())
    });
}

/// Merge property: merging sketches built over two halves answers within
/// the (documented) doubled budget of a sketch over the concatenation —
/// and both stay close to the exact percentiles.
#[test]
fn sketch_merge_equals_concatenated_stream_within_eps() {
    check(10, |g| {
        let kind_a = g.usize_range(0, 5);
        let kind_b = g.usize_range(0, 5);
        let na = g.usize_range(1_000, 15_000);
        let nb = g.usize_range(1_000, 15_000);
        let a_vals = adversarial_stream(kind_a, na, g.case_seed);
        let b_vals = adversarial_stream(kind_b, nb, g.case_seed.wrapping_add(1));

        let mut merged = QuantileSketch::default();
        let mut other = QuantileSketch::default();
        let mut concat = QuantileSketch::default();
        for &v in &a_vals {
            merged.insert(v);
            concat.insert(v);
        }
        for &v in &b_vals {
            other.insert(v);
            concat.insert(v);
        }
        merged.merge(&other);
        if merged.count() != (na + nb) as u64 {
            return Err(format!("merged count {} != {}", merged.count(), na + nb));
        }

        let mut sorted: Vec<f64> = a_vals;
        sorted.extend_from_slice(&b_vals);
        sorted.sort_by(f64::total_cmp);
        for &q in &[0.5, 0.99] {
            for (label, sk) in [("merged", &merged), ("concat", &concat)] {
                let err = rank_error(&sorted, sk.quantile(q), q);
                // Concatenated stream: ε budget. Merged: ε_a + ε_b.
                let tol = if label == "merged" { 0.03 } else { 0.015 };
                if err > tol {
                    return Err(format!(
                        "{label} q {q}: rank error {err:.4} > {tol} \
                         (kinds {kind_a}/{kind_b}, n {na}+{nb})"
                    ));
                }
            }
        }
        Ok(())
    });
}

fn finished_request(id: u64, base: f64, gaps: &[f64], slo: Option<f64>) -> Request {
    let mut r = Request::new(id, 0.0, 16, gaps.len() as u64 + 1);
    if let Some(s) = slo {
        r = r.with_slo_tbt(s);
    }
    r.advance_prefill(16);
    let mut t = base;
    r.advance_decode(t);
    for g in gaps {
        t += g;
        r.advance_decode(t);
    }
    r
}

/// `Recorder::merge` of streaming recorders ≡ one streaming recorder fed
/// the concatenated request stream: every counted field exactly, means
/// within float noise, percentiles within the sketch merge budget — and
/// the PR-2 SLO-attainment fields survive exactly.
#[test]
fn streaming_recorder_merge_equals_concatenated_feed() {
    check(8, |g| {
        let n_a = g.usize_range(50, 400);
        let n_b = g.usize_range(50, 400);
        let mut a = Recorder::streaming();
        let mut b = Recorder::streaming();
        let mut concat = Recorder::streaming();
        let mut mk = |i: usize, which: u64| {
            let gap = 0.01 + ((i as u64 * 37 + which * 13) % 100) as f64 * 1e-3;
            let slo = if i % 3 == 0 { Some(0.05) } else { None };
            let base = 0.2 + i as f64 * 0.01;
            finished_request(which * 10_000 + i as u64, base, &[gap, gap * 2.0], slo)
        };
        for i in 0..n_a {
            let r = mk(i, 0);
            a.record_finished(&r);
            concat.record_finished(&r);
        }
        for i in 0..n_b {
            let r = mk(i, 1);
            b.record_finished(&r);
            concat.record_finished(&r);
        }
        a.merge(&b);
        a.duration = 100.0;
        concat.duration = 100.0;

        let ra = a.report("merged");
        let rc = concat.report("concat");
        if ra.completed != rc.completed || ra.tbt.n != rc.tbt.n {
            return Err(format!(
                "counts diverge: completed {}/{}, tbt n {}/{}",
                ra.completed,
                rc.completed,
                ra.tbt.n,
                rc.tbt.n
            ));
        }
        if (a.slo_checked, a.slo_violations) != (concat.slo_checked, concat.slo_violations) {
            return Err(format!(
                "slo counts diverge: {}/{} vs {}/{}",
                a.slo_checked,
                a.slo_violations,
                concat.slo_checked,
                concat.slo_violations
            ));
        }
        match (ra.slo_attainment, rc.slo_attainment) {
            (Some(x), Some(y)) if (x - y).abs() < 1e-12 => {}
            (None, None) => {}
            other => return Err(format!("slo attainment diverged: {other:?}")),
        }
        let close = |x: f64, y: f64| (x - y).abs() <= 1e-9 * (1.0 + x.abs().max(y.abs()));
        if !close(ra.tbt.mean, rc.tbt.mean) || !close(ra.ttft.mean, rc.ttft.mean) {
            return Err(format!(
                "means diverge: tbt {} vs {}, ttft {} vs {}",
                ra.tbt.mean,
                rc.tbt.mean,
                ra.ttft.mean,
                rc.ttft.mean
            ));
        }
        // Extrema are exact in streaming mode.
        if ra.tbt.min != rc.tbt.min || ra.tbt.max != rc.tbt.max {
            return Err("extrema diverge".into());
        }
        // Percentiles: both are sketch answers; merged carries the
        // doubled budget. Compare against each other in value space via
        // rank error over an exactly reconstructed gap list.
        let mut gaps: Vec<f64> = Vec::new();
        for i in 0..n_a {
            let g0 = 0.01 + ((i as u64 * 37) % 100) as f64 * 1e-3;
            gaps.push(g0);
            gaps.push(g0 * 2.0);
        }
        for i in 0..n_b {
            let g0 = 0.01 + ((i as u64 * 37 + 13) % 100) as f64 * 1e-3;
            gaps.push(g0);
            gaps.push(g0 * 2.0);
        }
        gaps.sort_by(f64::total_cmp);
        for (label, rep) in [("merged", &ra), ("concat", &rc)] {
            for (q, got) in [(0.5, rep.tbt.p50), (0.99, rep.tbt.p99)] {
                let err = rank_error(&gaps, got, q);
                if err > 0.03 {
                    return Err(format!("{label} tbt q{q}: rank error {err:.4}"));
                }
            }
        }
        Ok(())
    });
}

/// Streaming recorders agree with exact recorders on everything exact
/// (counts, means, extrema, SLO fields) for the same request stream.
#[test]
fn streaming_report_matches_exact_report_on_exact_fields() {
    let mut exact = Recorder::new();
    let mut stream = Recorder::streaming();
    assert_eq!(exact.mode(), RecorderMode::Exact);
    assert_eq!(stream.mode(), RecorderMode::Streaming);
    for i in 0..300u64 {
        let gap = 0.02 + (i % 50) as f64 * 1e-3;
        let r = finished_request(i, 0.1 + i as f64 * 0.05, &[gap, gap, gap * 3.0], Some(0.06));
        exact.record_finished(&r);
        stream.record_finished(&r);
    }
    exact.duration = 50.0;
    stream.duration = 50.0;
    let re = exact.report("exact");
    let rs = stream.report("stream");
    assert_eq!(re.completed, rs.completed);
    assert_eq!((re.ttft.n, re.tbt.n, re.e2e.n), (rs.ttft.n, rs.tbt.n, rs.e2e.n));
    let close = |x: f64, y: f64| (x - y).abs() <= 1e-9 * (1.0 + x.abs().max(y.abs()));
    assert!(close(re.ttft.mean, rs.ttft.mean));
    assert!(close(re.tbt.mean, rs.tbt.mean));
    assert!(close(re.e2e.mean, rs.e2e.mean));
    assert!(close(re.tbt.std, rs.tbt.std), "std {} vs {}", re.tbt.std, rs.tbt.std);
    assert_eq!(re.tbt.min, rs.tbt.min);
    assert_eq!(re.tbt.max, rs.tbt.max);
    assert_eq!(re.slo_attainment, rs.slo_attainment);
    // Approximate percentiles land within the sketch budget of exact.
    let rel = (re.tbt.p99 - rs.tbt.p99).abs() / re.tbt.p99.max(1e-12);
    assert!(rel < 0.2, "p99 {} vs exact {}", rs.tbt.p99, re.tbt.p99);
}
