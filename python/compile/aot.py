"""AOT compile path: lower the L2 model to HLO *text* artifacts that the
rust runtime loads via the PJRT C API.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits:
    prefill_s64.hlo.txt          prefill over a 64-token padded prompt
    decode_b{1,2,4,8}.hlo.txt    one decode step per batch-size variant
    weights.bin                  all weights, f32 LE, manifest order
    weights.manifest.txt         name shape offset_bytes size_bytes
    artifacts.meta.txt           model shape constants for the rust side

HLO text (NOT serialized protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Weights are *runtime inputs* (flat list, manifest order), not baked
constants — this keeps the HLO text small and lets the rust side own the
parameter memory.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg: M.TinyConfig):
    wspecs = [
        jax.ShapeDtypeStruct(M.weight_shapes(cfg)[n], jnp.float32)
        for n in M.weight_names(cfg)
    ]
    tok = jax.ShapeDtypeStruct((cfg.prefill_seq,), jnp.int32)

    def fn(weights, tokens):
        return M.prefill(weights, tokens, cfg)

    return jax.jit(fn).lower(wspecs, tok)


def lower_decode(cfg: M.TinyConfig, batch: int):
    wspecs = [
        jax.ShapeDtypeStruct(M.weight_shapes(cfg)[n], jnp.float32)
        for n in M.weight_names(cfg)
    ]
    tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    cache = jax.ShapeDtypeStruct(
        (cfg.layers, batch, cfg.max_context, cfg.kv_heads, cfg.head_dim),
        jnp.float32,
    )
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)

    def fn(weights, tokens, kc, vc, lengths):
        return M.decode(weights, tokens, kc, vc, lengths, cfg)

    return jax.jit(fn).lower(wspecs, tok, cache, cache, lens)


def write_weights(cfg: M.TinyConfig, out_dir: str, seed: int = 0):
    weights = M.init_weights(cfg, seed)
    names = M.weight_names(cfg)
    bin_path = os.path.join(out_dir, "weights.bin")
    man_path = os.path.join(out_dir, "weights.manifest.txt")
    offset = 0
    with open(bin_path, "wb") as fb, open(man_path, "w") as fm:
        fm.write("# name shape offset_bytes size_bytes (f32 little-endian)\n")
        for name, w in zip(names, weights):
            import numpy as np

            arr = np.asarray(w, dtype="<f4")
            data = arr.tobytes()
            fb.write(data)
            shape = "x".join(str(d) for d in arr.shape)
            fm.write(f"{name} {shape} {offset} {len(data)}\n")
            offset += len(data)
    return bin_path, offset


def write_meta(cfg: M.TinyConfig, out_dir: str):
    with open(os.path.join(out_dir, "artifacts.meta.txt"), "w") as f:
        f.write(
            "# tiny-model serving constants (shared with rust runtime)\n"
            f"hidden = {cfg.hidden}\n"
            f"layers = {cfg.layers}\n"
            f"heads = {cfg.heads}\n"
            f"kv_heads = {cfg.kv_heads}\n"
            f"head_dim = {cfg.head_dim}\n"
            f"intermediate = {cfg.intermediate}\n"
            f"vocab = {cfg.vocab}\n"
            f"prefill_seq = {cfg.prefill_seq}\n"
            f"max_context = {cfg.max_context}\n"
            f"decode_batches = \"{','.join(str(b) for b in cfg.decode_batches)}\"\n"
            f"n_weights = {len(M.weight_names(cfg))}\n"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = M.TINY
    os.makedirs(args.out_dir, exist_ok=True)

    path = os.path.join(args.out_dir, f"prefill_s{cfg.prefill_seq}.hlo.txt")
    text = to_hlo_text(lower_prefill(cfg))
    open(path, "w").write(text)
    print(f"wrote {path} ({len(text)} chars)")

    for b in cfg.decode_batches:
        path = os.path.join(args.out_dir, f"decode_b{b}.hlo.txt")
        text = to_hlo_text(lower_decode(cfg, b))
        open(path, "w").write(text)
        print(f"wrote {path} ({len(text)} chars)")

    bin_path, nbytes = write_weights(cfg, args.out_dir, args.seed)
    print(f"wrote {bin_path} ({nbytes} bytes)")
    write_meta(cfg, args.out_dir)
    print("wrote artifacts.meta.txt")


if __name__ == "__main__":
    main()
