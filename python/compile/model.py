"""Layer-2: Qwen3-style tiny transformer in JAX, calling the L1 Pallas
kernels.

The model's *shapes* mirror `rust/src/config/model.rs::ModelSpec::tiny()`
(hidden 256, 4 layers, 8 q-heads / 4 kv-heads, head_dim 32, FFN 1024,
vocab 2048): a ~5M-parameter Qwen3-flavoured decoder (RMSNorm, RoPE, GQA
attention, SwiGLU MLP, untied LM head).

Two entry points are lowered AOT (see aot.py):

- ``prefill(weights, tokens[S]) -> (logits[S, V], k[L,S,hkv,dh], v[...])``
- ``decode(weights, tokens[B], k[L,B,C,hkv,dh], v[...], lengths[B])
    -> (logits[B, V], k', v')``

Weights are passed as a flat list (not baked as constants) so the HLO
stays small and the rust runtime feeds them from ``weights.bin``. The
flat ordering is defined by ``weight_names()`` and checked in tests.

Set DUET_USE_REF=1 to route attention through the pure-jnp oracle instead
of the Pallas kernels (A/B debugging).
"""

import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import attention as pallas_attn
from .kernels import ref as attn_ref

USE_REF = os.environ.get("DUET_USE_REF", "0") == "1"


@dataclass(frozen=True)
class TinyConfig:
    hidden: int = 256
    layers: int = 4
    heads: int = 8
    kv_heads: int = 4
    head_dim: int = 32
    intermediate: int = 1024
    vocab: int = 2048
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6

    # AOT serving shapes (the rust coordinator pads to these).
    prefill_seq: int = 64
    max_context: int = 320
    decode_batches: tuple = (1, 2, 4, 8)


TINY = TinyConfig()


# --------------------------------------------------------------------------
# Weights
# --------------------------------------------------------------------------

def weight_names(cfg: TinyConfig = TINY):
    """Flat weight ordering shared with the rust runtime (manifest order)."""
    names = ["tok_embedding"]
    for i in range(cfg.layers):
        names += [
            f"l{i}.attn_norm",
            f"l{i}.wq",
            f"l{i}.wk",
            f"l{i}.wv",
            f"l{i}.wo",
            f"l{i}.mlp_norm",
            f"l{i}.w_gate",
            f"l{i}.w_up",
            f"l{i}.w_down",
        ]
    names += ["final_norm", "lm_head"]
    return names


def weight_shapes(cfg: TinyConfig = TINY):
    d, dh = cfg.hidden, cfg.head_dim
    hq, hkv, m, v = cfg.heads, cfg.kv_heads, cfg.intermediate, cfg.vocab
    per_layer = {
        "attn_norm": (d,),
        "wq": (d, hq * dh),
        "wk": (d, hkv * dh),
        "wv": (d, hkv * dh),
        "wo": (hq * dh, d),
        "mlp_norm": (d,),
        "w_gate": (d, m),
        "w_up": (d, m),
        "w_down": (m, d),
    }
    shapes = {"tok_embedding": (v, d)}
    for i in range(cfg.layers):
        for k, s in per_layer.items():
            shapes[f"l{i}.{k}"] = s
    shapes["final_norm"] = (d,)
    shapes["lm_head"] = (d, v)
    return shapes


def init_weights(cfg: TinyConfig = TINY, seed: int = 0):
    """Seeded random weights, returned as the flat ordered list."""
    shapes = weight_shapes(cfg)
    out = []
    key = jax.random.PRNGKey(seed)
    for name in weight_names(cfg):
        key, sub = jax.random.split(key)
        shape = shapes[name]
        if name.endswith("norm"):
            w = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            w = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(
                jnp.asarray(fan_in, jnp.float32)
            )
        out.append(w)
    return out


def _unflatten(cfg, weights):
    names = weight_names(cfg)
    assert len(weights) == len(names), (len(weights), len(names))
    return dict(zip(names, weights))


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def rms_norm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta):
    """Rotary embedding. x: [..., n_heads, dh]; positions broadcastable to
    x.shape[:-2]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention_prefill(q, k, v):
    if USE_REF:
        return attn_ref.prefill_attention_ref(q, k, v)
    return pallas_attn.prefill_attention(q, k, v)


def _attention_decode(q, kc, vc, lengths):
    if USE_REF:
        return attn_ref.decode_attention_ref(q, kc, vc, lengths)
    return pallas_attn.decode_attention(q, kc, vc, lengths)


# --------------------------------------------------------------------------
# Prefill: whole (padded) prompt in one pass
# --------------------------------------------------------------------------

def prefill(weights, tokens, cfg: TinyConfig = TINY):
    """tokens: int32 [S]. Returns (logits [S, V], k [L,S,hkv,dh], v [...]).

    The rust coordinator right-pads prompts to S; causal masking keeps
    positions < true length correct, and rust reads logits[len-1].
    """
    w = _unflatten(cfg, weights)
    s = tokens.shape[0]
    x = w["tok_embedding"][tokens]  # [S, d]
    positions = jnp.arange(s)
    ks, vs = [], []
    for i in range(cfg.layers):
        h = rms_norm(x, w[f"l{i}.attn_norm"], cfg.norm_eps)
        q = (h @ w[f"l{i}.wq"]).reshape(s, cfg.heads, cfg.head_dim)
        k = (h @ w[f"l{i}.wk"]).reshape(s, cfg.kv_heads, cfg.head_dim)
        v = (h @ w[f"l{i}.wv"]).reshape(s, cfg.kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        o = _attention_prefill(q, k, v)  # L1 kernel
        x = x + o.reshape(s, -1) @ w[f"l{i}.wo"]
        h = rms_norm(x, w[f"l{i}.mlp_norm"], cfg.norm_eps)
        x = x + (jax.nn.silu(h @ w[f"l{i}.w_gate"]) * (h @ w[f"l{i}.w_up"])) @ w[
            f"l{i}.w_down"
        ]
        ks.append(k)
        vs.append(v)
    x = rms_norm(x, w["final_norm"], cfg.norm_eps)
    logits = x @ w["lm_head"]  # [S, V]
    return logits, jnp.stack(ks), jnp.stack(vs)


# --------------------------------------------------------------------------
# Decode: one token per slot against the KV cache
# --------------------------------------------------------------------------

def decode(weights, tokens, k_cache, v_cache, lengths, cfg: TinyConfig = TINY):
    """One decode step for a batch of slots.

    tokens: int32 [B] (current input token per slot);
    k_cache/v_cache: f32 [L, B, C, hkv, dh];
    lengths: int32 [B] — valid cache positions BEFORE this token.
    Returns (logits [B, V], k_cache', v_cache'); the new token's K/V is
    written at position `lengths[b]`.
    Inactive slots: lengths[b] = 0 with any token produce garbage logits
    the coordinator ignores (no branching in the graph).
    """
    w = _unflatten(cfg, weights)
    b = tokens.shape[0]
    c = k_cache.shape[2]
    x = w["tok_embedding"][tokens]  # [B, d]
    positions = lengths  # 0-based position of the incoming token
    new_ks, new_vs = [], []
    for i in range(cfg.layers):
        h = rms_norm(x, w[f"l{i}.attn_norm"], cfg.norm_eps)
        q = (h @ w[f"l{i}.wq"]).reshape(b, cfg.heads, cfg.head_dim)
        k = (h @ w[f"l{i}.wk"]).reshape(b, cfg.kv_heads, cfg.head_dim)
        v = (h @ w[f"l{i}.wv"]).reshape(b, cfg.kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        # Insert new K/V at position lengths[b] for each slot.
        kc = k_cache[i]
        vc = v_cache[i]
        onehot = (jnp.arange(c)[None, :] == lengths[:, None]).astype(kc.dtype)
        kc = kc * (1.0 - onehot[..., None, None]) + onehot[..., None, None] * k[:, None]
        vc = vc * (1.0 - onehot[..., None, None]) + onehot[..., None, None] * v[:, None]
        o = _attention_decode(q, kc, vc, lengths + 1)  # L1 kernel
        x = x + o.reshape(b, -1) @ w[f"l{i}.wo"]
        h = rms_norm(x, w[f"l{i}.mlp_norm"], cfg.norm_eps)
        x = x + (jax.nn.silu(h @ w[f"l{i}.w_gate"]) * (h @ w[f"l{i}.w_up"])) @ w[
            f"l{i}.w_down"
        ]
        new_ks.append(kc)
        new_vs.append(vc)
    x = rms_norm(x, w["final_norm"], cfg.norm_eps)
    logits = x @ w["lm_head"]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def greedy_generate_ref(weights, prompt, n_new, cfg: TinyConfig = TINY):
    """Reference end-to-end generation (prefill + decode loop) used by
    tests to validate the AOT artifacts' composition semantics."""
    s = len(prompt)
    pad = jnp.zeros(cfg.prefill_seq - s, jnp.int32)
    tokens = jnp.concatenate([jnp.asarray(prompt, jnp.int32), pad])
    logits, k, v = prefill(weights, tokens, cfg)
    # Per-slot batched cache of size 1.
    kc = jnp.zeros((cfg.layers, 1, cfg.max_context, cfg.kv_heads, cfg.head_dim))
    vc = jnp.zeros_like(kc)
    kc = kc.at[:, 0, :s].set(k[:, :s])
    vc = vc.at[:, 0, :s].set(v[:, :s])
    out = [int(jnp.argmax(logits[s - 1]))]
    length = s
    for _ in range(n_new - 1):
        tok = jnp.asarray([out[-1]], jnp.int32)
        logits, kc, vc = decode(
            weights, tok, kc, vc, jnp.asarray([length], jnp.int32), cfg
        )
        out.append(int(jnp.argmax(logits[0])))
        length += 1
    return out
