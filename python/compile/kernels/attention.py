"""Layer-1 Pallas attention kernels.

Two kernels, matching the two serving phases the paper multiplexes:

- ``prefill_attention``: FlashAttention-style causal attention with
  online softmax. The TPU rethink of the paper's FA-3 dependency: KV is
  streamed HBM->VMEM in ``BLOCK_K``-sized tiles via BlockSpec (the role
  CUDA threadblock tiling into SRAM plays on H100), the q·kᵀ / p·v
  contractions are MXU-shaped matmuls, and the causal structure is
  expressed by skipping fully-masked KV tiles inside the kernel.

- ``decode_attention``: single-token attention against a per-slot KV
  cache (the DuetServe decode path that the rust coordinator replays
  CUDA-Graph-style). Grid over batch slots; each program streams one
  slot's cache through VMEM with a length mask.

Both kernels MUST run ``interpret=True`` here: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and correctness (vs ``ref.py``) is what the
AOT path needs. Real-TPU tiling estimates live in DESIGN.md
§Hardware-Adaptation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# VMEM tile sizes. On a real TPU these would be tuned to ~16 MB VMEM; in
# interpret mode they only shape the loop structure (kept small so tiny
# test shapes divide evenly).
BLOCK_Q = 16
BLOCK_K = 16


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, seq_len, block_k):
    """One (head, q-tile) program: online-softmax over KV tiles.

    q_ref: [BLOCK_Q, d]; k_ref/v_ref: [S, d] (whole-row block for this
    head); o_ref: [BLOCK_Q, d].
    """
    qi = pl.program_id(1)  # q-tile index
    q = q_ref[...].astype(jnp.float32) * scale
    block_q = q.shape[0]
    d = q.shape[1]

    acc = jnp.zeros((block_q, d), jnp.float32)
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # absolute q rows

    def body(ki, carry):
        acc, m, l = carry
        k_tile = jax.lax.dynamic_slice_in_dim(k_ref[...], ki * block_k, block_k, 0)
        v_tile = jax.lax.dynamic_slice_in_dim(v_ref[...], ki * block_k, block_k, 0)
        k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
        s = q @ k_tile.astype(jnp.float32).T  # [BLOCK_Q, BLOCK_K] (MXU)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + p.sum(axis=1)
        acc_new = acc * correction[:, None] + p @ v_tile.astype(jnp.float32)
        return acc_new, m_new, l_new

    n_k_tiles = seq_len // block_k
    acc, m, l = jax.lax.fori_loop(0, n_k_tiles, body, (acc, m, l))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def prefill_attention(q, k, v, *, block_q=BLOCK_Q, block_k=BLOCK_K, interpret=True):
    """Causal GQA attention. q: [S, h_q, d], k/v: [S, h_kv, d] -> [S, h_q, d]."""
    s, hq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, "GQA ratio must be integral"
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    group = hq // hkv
    scale = 1.0 / (d**0.5)

    kernel = functools.partial(
        _prefill_kernel, scale=scale, seq_len=s, block_k=block_k
    )
    grid = (hq, s // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        # q is tiled over (head, q-block); k/v expose the whole row for the
        # matching kv-head (index maps fold the GQA grouping). `None`
        # entries squeeze the head dim inside the kernel.
        in_specs=[
            pl.BlockSpec((block_q, None, d), lambda h, i: (i, h, 0)),
            pl.BlockSpec((s, None, d), lambda h, i: (0, h // group, 0)),
            pl.BlockSpec((s, None, d), lambda h, i: (0, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, None, d), lambda h, i: (i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((s, hq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale, ctx, block_k):
    """One (batch-slot, head) program: masked attention over the cache.

    q_ref: [1, d]; k_ref/v_ref: [C, d]; len_ref: [1] (valid positions);
    o_ref: [1, d].
    """
    q = q_ref[...].astype(jnp.float32) * scale  # [1, d]
    valid = len_ref[0]
    d = q.shape[1]

    acc = jnp.zeros((1, d), jnp.float32)
    m = jnp.full((1,), NEG_INF, jnp.float32)
    l = jnp.zeros((1,), jnp.float32)

    def body(ki, carry):
        acc, m, l = carry
        k_tile = jax.lax.dynamic_slice_in_dim(k_ref[...], ki * block_k, block_k, 0)
        v_tile = jax.lax.dynamic_slice_in_dim(v_ref[...], ki * block_k, block_k, 0)
        pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
        s = q @ k_tile.astype(jnp.float32).T  # [1, BLOCK_K]
        s = jnp.where((pos < valid)[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + p.sum(axis=1)
        acc_new = acc * correction[:, None] + p @ v_tile.astype(jnp.float32)
        return acc_new, m_new, l_new

    acc, m, l = jax.lax.fori_loop(0, ctx // block_k, body, (acc, m, l))
    # Fully-masked rows (valid == 0) would divide by zero; emit zeros.
    safe_l = jnp.where(l > 0.0, l, 1.0)
    o_ref[...] = (acc / safe_l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, block_k=BLOCK_K, interpret=True):
    """Decode-step GQA attention against per-slot caches.

    q: [B, h_q, d]; k_cache/v_cache: [B, C, h_kv, d]; lengths: [B] int32
    (#valid positions incl. the just-inserted token). Returns [B, h_q, d].
    """
    b, hq, d = q.shape
    c, hkv = k_cache.shape[1], k_cache.shape[2]
    assert hq % hkv == 0
    assert c % block_k == 0, (c, block_k)
    group = hq // hkv
    scale = 1.0 / (d**0.5)

    kernel = functools.partial(_decode_kernel, scale=scale, ctx=c, block_k=block_k)
    grid = (b, hq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 1, d), lambda bi, h: (bi, h, 0)),
            pl.BlockSpec((None, c, None, d), lambda bi, h: (bi, 0, h // group, 0)),
            pl.BlockSpec((None, c, None, d), lambda bi, h: (bi, 0, h // group, 0)),
            pl.BlockSpec((1,), lambda bi, h: (bi,)),
        ],
        out_specs=pl.BlockSpec((None, 1, d), lambda bi, h: (bi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, lengths)
