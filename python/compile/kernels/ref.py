"""Pure-jnp oracles for the Pallas attention kernels.

These are the correctness ground truth: pytest checks every Pallas kernel
against these implementations (allclose), and the model may swap them in
via DUET_USE_REF=1 to isolate kernel bugs from model bugs.
"""

import jax.numpy as jnp


def repeat_kv(x, n_rep: int):
    """[.., h_kv, d] -> [.., h_kv * n_rep, d] (GQA head replication)."""
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=-2)


def prefill_attention_ref(q, k, v, scale=None):
    """Causal self-attention over one sequence.

    q: [S, h_q, d], k/v: [S, h_kv, d]  ->  [S, h_q, d]
    """
    s, hq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    k = repeat_kv(k, hq // hkv)  # [S, hq, d]
    v = repeat_kv(v, hq // hkv)
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    # [hq, S, S]
    logits = jnp.einsum("qhd,khd->hqk", q, k) * scale
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(causal[None, :, :], logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", p, v)


def decode_attention_ref(q, k_cache, v_cache, lengths, scale=None):
    """Single-token decode attention against a per-slot KV cache.

    q: [B, h_q, d]; k_cache/v_cache: [B, C, h_kv, d]; lengths: [B] — the
    number of valid cache positions per slot *including* the current
    token's K/V (callers insert the new K/V before attending).
    Returns [B, h_q, d].
    """
    b, hq, d = q.shape
    c = k_cache.shape[1]
    hkv = k_cache.shape[2]
    k = repeat_kv(k_cache, hq // hkv)  # [B, C, hq, d]
    v = repeat_kv(v_cache, hq // hkv)
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = jnp.einsum("bhd,bchd->bhc", q, k) * scale
    mask = jnp.arange(c)[None, :] < lengths[:, None]  # [B, C]
    logits = jnp.where(mask[:, None, :], logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhc,bchd->bhd", p, v)
