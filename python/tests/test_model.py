"""L2 correctness: model shapes, prefill/decode cache consistency, and
Pallas-vs-ref end-to-end agreement."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.TINY


def test_weight_manifest_order_is_stable():
    names = M.weight_names(CFG)
    assert names[0] == "tok_embedding"
    assert names[-1] == "lm_head"
    assert names[-2] == "final_norm"
    assert len(names) == 2 + 9 * CFG.layers + 1
    shapes = M.weight_shapes(CFG)
    assert set(names) == set(shapes.keys())


def test_param_count_matches_rust_tiny():
    # rust ModelSpec::tiny().param_count() counts emb + blocks + norms +
    # lm_head with the same formulas; keep the two in the same ballpark.
    shapes = M.weight_shapes(CFG)
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert 4_000_000 < total < 8_000_000, total


def test_prefill_shapes():
    w = M.init_weights(CFG)
    toks = jnp.zeros((CFG.prefill_seq,), jnp.int32)
    logits, k, v = M.prefill(w, toks)
    assert logits.shape == (CFG.prefill_seq, CFG.vocab)
    assert k.shape == (CFG.layers, CFG.prefill_seq, CFG.kv_heads, CFG.head_dim)
    assert v.shape == k.shape
    assert bool(jnp.isfinite(logits).all())


def test_decode_step_shapes_and_cache_update():
    w = M.init_weights(CFG)
    b = 2
    kc = jnp.zeros((CFG.layers, b, CFG.max_context, CFG.kv_heads, CFG.head_dim))
    vc = jnp.zeros_like(kc)
    toks = jnp.asarray([3, 7], jnp.int32)
    lens = jnp.asarray([0, 5], jnp.int32)
    logits, kc2, vc2 = M.decode(w, toks, kc, vc, lens)
    assert logits.shape == (b, CFG.vocab)
    # new K/V written exactly at position lengths[b]
    assert not np.allclose(kc2[:, 0, 0], 0.0)
    assert np.allclose(kc2[:, 0, 1:], 0.0)
    assert not np.allclose(kc2[:, 1, 5], 0.0)
    assert np.allclose(kc2[:, 1, 6:], 0.0)
    assert np.allclose(kc2[:, 1, :5], 0.0)  # untouched (was zero)


def test_decode_matches_extended_prefill():
    """Token t+1 from the decode path == argmax from prefill over the
    extended prompt: the KV-cache state machine is consistent."""
    w = M.init_weights(CFG)
    prompt = [11, 500, 42, 1999, 8]
    out = M.greedy_generate_ref(w, prompt, 4)
    for i in range(1, 4):
        ext = prompt + out[:i]
        toks = jnp.asarray(
            ext + [0] * (CFG.prefill_seq - len(ext)), jnp.int32
        )
        logits, _, _ = M.prefill(w, toks)
        assert int(jnp.argmax(logits[len(ext) - 1])) == out[i], f"step {i}"


def test_padding_does_not_change_logits():
    w = M.init_weights(CFG)
    prompt = [4, 8, 15, 16, 23, 42]
    s = len(prompt)
    t1 = jnp.asarray(prompt + [0] * (CFG.prefill_seq - s), jnp.int32)
    t2 = jnp.asarray(prompt + [99] * (CFG.prefill_seq - s), jnp.int32)
    l1, _, _ = M.prefill(w, t1)
    l2, _, _ = M.prefill(w, t2)
    np.testing.assert_allclose(l1[: s], l2[: s], rtol=1e-5, atol=1e-5)


def test_pallas_and_ref_models_agree():
    """Whole-model A/B: attention via Pallas kernels vs via the oracle."""
    w = M.init_weights(CFG)
    toks = jnp.asarray([1, 2, 3] + [0] * (CFG.prefill_seq - 3), jnp.int32)
    logits_pallas, k1, v1 = M.prefill(w, toks)

    os.environ["DUET_USE_REF"] = "1"
    try:
        import importlib

        importlib.reload(M)
        w2 = M.init_weights(M.TINY)
        logits_ref, k2, v2 = M.prefill(w2, toks)
    finally:
        os.environ["DUET_USE_REF"] = "0"
        import importlib

        importlib.reload(M)

    np.testing.assert_allclose(logits_pallas, logits_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(k1, k2, rtol=1e-5, atol=1e-5)


def test_deterministic_weights():
    a = M.init_weights(CFG, seed=0)
    b = M.init_weights(CFG, seed=0)
    c = M.init_weights(CFG, seed=1)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.allclose(x, y) for x, y in zip(a, c))
