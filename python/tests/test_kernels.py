"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is the
core correctness signal for the whole AOT path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- prefill

@settings(max_examples=20, deadline=None)
@given(
    s_tiles=st.integers(1, 6),
    hq_per_kv=st.sampled_from([1, 2, 4]),
    hkv=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_prefill_matches_ref_across_shapes(s_tiles, hq_per_kv, hkv, dh, seed):
    s = 16 * s_tiles
    hq = hq_per_kv * hkv
    q = rand(seed, (s, hq, dh))
    k = rand(seed + 1, (s, hkv, dh))
    v = rand(seed + 2, (s, hkv, dh))
    out = A.prefill_attention(q, k, v)
    ref = R.prefill_attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_prefill_is_causal():
    # Changing future K/V must not affect earlier outputs.
    s, hq, hkv, dh = 32, 4, 2, 16
    q, k, v = rand(0, (s, hq, dh)), rand(1, (s, hkv, dh)), rand(2, (s, hkv, dh))
    base = A.prefill_attention(q, k, v)
    k2 = k.at[-1].set(100.0)
    v2 = v.at[-1].set(-100.0)
    pert = A.prefill_attention(q, k2, v2)
    np.testing.assert_allclose(base[: s - 1], pert[: s - 1], rtol=1e-6)
    assert not np.allclose(base[-1], pert[-1])


def test_prefill_block_sizes_agree():
    s, hq, hkv, dh = 64, 8, 4, 32
    q, k, v = rand(3, (s, hq, dh)), rand(4, (s, hkv, dh)), rand(5, (s, hkv, dh))
    a = A.prefill_attention(q, k, v, block_q=16, block_k=16)
    b = A.prefill_attention(q, k, v, block_q=32, block_k=64)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_prefill_rejects_ragged_shapes():
    q, k, v = rand(0, (20, 4, 16)), rand(1, (20, 2, 16)), rand(2, (20, 2, 16))
    with pytest.raises(AssertionError):
        A.prefill_attention(q, k, v)  # 20 % 16 != 0


# ---------------------------------------------------------------- decode

@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    c_tiles=st.integers(1, 8),
    hq_per_kv=st.sampled_from([1, 2]),
    hkv=st.sampled_from([2, 4]),
    dh=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
def test_decode_matches_ref_across_shapes(b, c_tiles, hq_per_kv, hkv, dh, seed):
    c = 16 * c_tiles
    hq = hq_per_kv * hkv
    rng = np.random.RandomState(seed)
    q = rand(seed, (b, hq, dh))
    kc = rand(seed + 1, (b, c, hkv, dh))
    vc = rand(seed + 2, (b, c, hkv, dh))
    lengths = jnp.asarray(rng.randint(1, c + 1, size=b), jnp.int32)
    out = A.decode_attention(q, kc, vc, lengths)
    ref = R.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_decode_respects_length_mask():
    # Positions beyond `lengths` must not influence the output.
    b, c, hq, hkv, dh = 2, 64, 4, 2, 16
    q = rand(0, (b, hq, dh))
    kc = rand(1, (b, c, hkv, dh))
    vc = rand(2, (b, c, hkv, dh))
    lengths = jnp.asarray([10, 30], jnp.int32)
    base = A.decode_attention(q, kc, vc, lengths)
    kc2 = kc.at[:, 40:].set(1e3)
    vc2 = vc.at[:, 40:].set(-1e3)
    pert = A.decode_attention(q, kc2, vc2, lengths)
    np.testing.assert_allclose(base, pert, rtol=1e-6)


def test_decode_zero_length_slot_is_finite():
    # An inactive slot (length 0) must not produce NaNs that poison XLA.
    b, c, hq, hkv, dh = 2, 32, 4, 2, 16
    q = rand(0, (b, hq, dh))
    kc = rand(1, (b, c, hkv, dh))
    vc = rand(2, (b, c, hkv, dh))
    lengths = jnp.asarray([0, 16], jnp.int32)
    out = A.decode_attention(q, kc, vc, lengths)
    assert bool(jnp.isfinite(out).all())


def test_decode_agrees_with_prefill_last_row():
    # Decode over a cache holding a prefix == prefill's last-row attention.
    s, hq, hkv, dh = 32, 4, 2, 16
    q_all = rand(0, (s, hq, dh))
    k_all = rand(1, (s, hkv, dh))
    v_all = rand(2, (s, hkv, dh))
    pre = A.prefill_attention(q_all, k_all, v_all)

    c = 64
    kc = jnp.zeros((1, c, hkv, dh)).at[0, :s].set(k_all)
    vc = jnp.zeros((1, c, hkv, dh)).at[0, :s].set(v_all)
    dec = A.decode_attention(
        q_all[-1][None], kc, vc, jnp.asarray([s], jnp.int32)
    )
    np.testing.assert_allclose(dec[0], pre[-1], rtol=2e-5, atol=2e-5)
