"""AOT path: lowered HLO text must exist, parse, and execute (via jax's
own CPU client) with results identical to eager execution. This is the
python half of the interchange contract; the rust half is covered by
`rust/tests/runtime_integration.rs`."""

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.TINY


def test_prefill_hlo_text_parses_and_runs():
    lowered = aot.lower_prefill(CFG)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "main" in text
    # Round-trip through the HLO text parser + CPU client = what rust does.
    client = xc.make_cpu_client()
    comp = xc._xla.hlo_module_from_text(text)
    # (Parsing alone exercises the id-reassignment path.)
    assert comp is not None

    # Execute via jax for ground truth comparison.
    w = M.init_weights(CFG)
    toks = jnp.asarray([9, 8, 7] + [0] * (CFG.prefill_seq - 3), jnp.int32)
    eager_logits, _, _ = M.prefill(w, toks)
    compiled = lowered.compile()
    aot_logits, _, _ = compiled(w, toks)
    np.testing.assert_allclose(aot_logits, eager_logits, rtol=1e-5, atol=1e-5)


def test_decode_variants_have_right_shapes():
    for b in CFG.decode_batches:
        lowered = aot.lower_decode(CFG, b)
        text = aot.to_hlo_text(lowered)
        assert f"f32[{CFG.layers},{b},{CFG.max_context}" in text.replace(" ", ""), (
            f"decode_b{b} missing cache shape"
        )


def test_weights_bin_roundtrip(tmp_path):
    out = str(tmp_path)
    aot.write_weights(CFG, out, seed=0)
    aot.write_meta(CFG, out)
    man = open(os.path.join(out, "weights.manifest.txt")).read().strip().splitlines()
    rows = [l.split() for l in man if not l.startswith("#")]
    assert len(rows) == len(M.weight_names(CFG))
    blob = open(os.path.join(out, "weights.bin"), "rb").read()
    # Offsets tile the blob exactly.
    total = sum(int(r[3]) for r in rows)
    assert total == len(blob)
    # Spot-check one tensor against init_weights.
    w = M.init_weights(CFG, seed=0)
    name, shape, off, size = rows[0][0], rows[0][1], int(rows[0][2]), int(rows[0][3])
    assert name == "tok_embedding"
    arr = np.frombuffer(blob[off : off + size], dtype="<f4").reshape(
        [int(x) for x in shape.split("x")]
    )
    np.testing.assert_array_equal(arr, np.asarray(w[0]))


def test_meta_file_contents(tmp_path):
    out = str(tmp_path)
    aot.write_meta(CFG, out)
    meta = open(os.path.join(out, "artifacts.meta.txt")).read()
    assert f"vocab = {CFG.vocab}" in meta
    assert f"prefill_seq = {CFG.prefill_seq}" in meta
    assert "decode_batches" in meta
