//! Streaming front-end demo: concurrent clients submit requests to the
//! unified serving front-end and stream tokens back while the engine
//! thread runs continuous batching — here over the *simulated* execution
//! backend, so the demo runs anywhere (no AOT artifacts needed) and the
//! token timestamps are engine-clock seconds from the same metrics
//! structs the paper's evaluation uses. Swap the backend for
//! `PjrtBackend` (see `e2e_serve`) and the identical lifecycle serves the
//! real AOT-compiled model.
//!
//! With `--replicas N` (N ≥ 2) the same lifecycle serves across an
//! N-worker cluster: each submission is routed at arrival time through
//! the pluggable `Router` seam (`--router`, default least-outstanding)
//! against live load signals, and the drain report is the workers'
//! merged recorder — streaming, cancel and backpressure are unchanged.
//!
//!     cargo run --release --example streaming_server
//!     cargo run --release --example streaming_server -- --replicas 3 --router kv-pressure
//!
//! The engine invariants are checked on the live drain path by
//! `ServerCore::finish` (which `shutdown` drives), not just on batch
//! runs.

use std::time::Instant;

use duetserve::cli::Args;
use duetserve::config::{Policy, ServingConfig};
use duetserve::server::{Server, SubmitOptions, TokenEvent};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let replicas = args.u32_or("replicas", 1);
    let router = args.str_or("router", "least-outstanding");
    let cfg = ServingConfig::default_8b().with_policy(Policy::Duet);
    let server = if replicas > 1 {
        println!(
            "starting engine thread ({replicas} DuetScheduler sim workers, \
             {router} routing)..."
        );
        Server::start_sim_replicated(cfg, replicas, 1, &router)?
    } else {
        println!("starting engine thread (DuetScheduler over the sim backend)...");
        Server::start_sim(cfg, 1)?
    };

    // 3 concurrent "client" threads, 4 requests each.
    let t0 = Instant::now();
    let server_ref = &server;
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..3u64 {
            let h = scope.spawn(move || {
                let mut results = Vec::new();
                for r in 0..4u64 {
                    let prompt: Vec<i32> = (0..2048 + 512 * (r as usize % 3))
                        .map(|j| ((c * 977 + r * 131 + j as u64 * 13) % 2048) as i32)
                        .collect();
                    let opts = SubmitOptions {
                        max_new_tokens: 12,
                        slo_tbt_ms: Some(100.0),
                        ..Default::default()
                    };
                    let handle = server_ref.submit(prompt, opts).expect("submit");
                    let events = handle.collect_events();
                    let times: Vec<f64> = events
                        .iter()
                        .filter_map(|e| match e {
                            TokenEvent::Token { at, .. } => Some(*at),
                            TokenEvent::Done { .. } => None,
                        })
                        .collect();
                    results.push((c, r, times));
                }
                results
            });
            joins.push(h);
        }
        for h in joins {
            for (c, r, times) in h.join().unwrap() {
                let ttft = times.first().copied().unwrap_or(0.0);
                let tbt = if times.len() > 1 {
                    (times.last().unwrap() - times.first().unwrap())
                        / (times.len() - 1) as f64
                } else {
                    0.0
                };
                println!(
                    "client {c} request {r}: {} tokens, first at {:.0} ms, \
                     mean gap {:.1} ms (engine clock)",
                    times.len(),
                    ttft * 1e3,
                    tbt * 1e3
                );
            }
        }
    });
    println!(
        "12 requests streamed concurrently in {:.2}s wall time",
        t0.elapsed().as_secs_f64()
    );

    // Drain and read the end-of-run report from the shared metrics
    // structs — the same TTFT/TBT accounting every simulated bench uses,
    // merged across workers when serving a cluster.
    let report = server.shutdown()?;
    println!(
        "report[{}]: {} completed; ttft mean {:.0} ms; tbt mean {:.1} ms \
         p99 {:.1} ms; slo attainment {}",
        report.system,
        report.completed,
        report.ttft.mean * 1e3,
        report.tbt.mean * 1e3,
        report.tbt_p99 * 1e3,
        report
            .slo_attainment
            .map(|a| format!("{:.1}%", a * 100.0))
            .unwrap_or_else(|| "n/a".into()),
    );
    println!("engine thread drained and stopped cleanly");
    Ok(())
}
