//! Streaming front-end demo: concurrent clients submit requests to the
//! threaded serving router and stream tokens back while the engine
//! thread runs continuous batching over the real PJRT model.
//!
//!     cargo run --release --example streaming_server

use std::time::Instant;

use duetserve::runtime::{artifacts, TinyRuntime};
use duetserve::server::Server;

fn main() -> anyhow::Result<()> {
    if !artifacts::artifacts_available() {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("starting engine thread (loads AOT artifacts)...");
    let server = Server::start(|| TinyRuntime::load_default(), 4);

    // 3 concurrent "client" threads, 4 requests each.
    let t0 = Instant::now();
    let server_ref = &server;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..3u64 {
            let h = scope.spawn(move || {
                let mut results = Vec::new();
                for r in 0..4u64 {
                    let prompt: Vec<i32> =
                        (0..10).map(|j| ((c * 977 + r * 131 + j * 13) % 2048) as i32).collect();
                    let stream = server_ref.submit(prompt, 12);
                    let start = stream.submitted_at;
                    let toks = stream.collect();
                    results.push((c, r, toks.len(), start.elapsed()));
                }
                results
            });
            handles.push(h);
        }
        for h in handles {
            for (c, r, n, dur) in h.join().unwrap() {
                println!(
                    "client {c} request {r}: {n} tokens in {:.0} ms",
                    dur.as_secs_f64() * 1e3
                );
            }
        }
    });
    println!(
        "12 requests served concurrently in {:.2}s total",
        t0.elapsed().as_secs_f64()
    );
    server.shutdown()?;
    println!("engine thread drained and stopped cleanly");
    Ok(())
}
