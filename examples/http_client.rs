//! HTTP transport demo and curl-free CI smoke: spawn `serve-http`
//! in-process on an ephemeral port, then act as a plain `std::net` HTTP
//! client against it — stream one completion over SSE, run one
//! non-streaming completion, probe `/healthz` and `/metrics`, and drain
//! with `POST /shutdown`.
//!
//!     cargo run --release --example http_client
//!     cargo run --release --example http_client -- --addr 127.0.0.1:8080
//!
//! With `--addr` the example skips spawning and talks to an
//! already-running `serve-http` instead (it will drain that server at
//! the end).
//!
//! With `--keep-alive N` the example instead runs N sequential
//! non-streaming completions over ONE kept-alive socket
//! (`Content-Length`-framed responses, no reconnect) and leaves the
//! server running — the CI soak uses this against a live `serve-http`
//! to drive a reused connection across engine-clock epochs. Optional
//! `--arrival-step S` stamps request i with an explicit engine-clock
//! arrival of `(i + 1) * S` seconds, and `--max-tokens K` sets the
//! per-request decode budget (default 6).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use duetserve::cli::Args;
use duetserve::config::{Policy, ServingConfig};
use duetserve::server::http::{HttpConfig, HttpServer};
use duetserve::server::{Server, ServerCore};
use duetserve::util::json;

fn connect(addr: SocketAddr) -> anyhow::Result<TcpStream> {
    let s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(60)))?;
    Ok(s)
}

/// One full request/response exchange (`Connection: close` semantics —
/// stated explicitly, so the keep-alive front door closes after the
/// response instead of parking the socket until idle-timeout); returns
/// (status, body).
fn exchange(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> anyhow::Result<(u16, String)> {
    let mut s = connect(addr)?;
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    if let Some(b) = body {
        req.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            b.len()
        ));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    s.write_all(req.as_bytes())?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("no status line in response: {resp:.120}"))?;
    let payload = resp
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, payload))
}

/// Read one `Content-Length`-framed response off a kept-alive socket;
/// returns (status, raw head, body).
fn read_framed(r: &mut BufReader<TcpStream>) -> anyhow::Result<(u16, String, String)> {
    let mut head = String::new();
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            anyhow::bail!("server closed the kept-alive socket mid-head");
        }
        if line == "\r\n" {
            break;
        }
        head.push_str(&line);
    }
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("no status line in framed response: {head}"))?;
    let len: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().ok())?
        })
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok((status, head, String::from_utf8_lossy(&body).into_owned()))
}

/// `--keep-alive N`: N sequential non-streaming completions over one
/// reused socket. Every response must come back `Connection:
/// keep-alive` and fully framed — one reconnect or short read fails the
/// run. `arrival_step > 0` stamps request i with an explicit
/// engine-clock arrival of `(i + 1) * arrival_step` seconds, which the
/// CI soak uses to march one socket across engine-clock epochs.
fn keep_alive_run(
    addr: SocketAddr,
    n: usize,
    arrival_step: f64,
    max_tokens: usize,
) -> anyhow::Result<()> {
    let s = connect(addr)?;
    s.set_nodelay(true).ok();
    let mut r = BufReader::new(s);
    for i in 0..n {
        let arrival = if arrival_step > 0.0 {
            format!(",\"arrival\":{}", (i as f64 + 1.0) * arrival_step)
        } else {
            String::new()
        };
        let body = format!("{{\"prompt\":[1,2,3,4],\"max_tokens\":{max_tokens}{arrival}}}");
        let req = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        r.get_mut().write_all(req.as_bytes())?;
        let (status, head, resp) = read_framed(&mut r)?;
        if status != 200 {
            anyhow::bail!("keep-alive request {i} failed ({status}): {resp}");
        }
        if !head.to_ascii_lowercase().contains("connection: keep-alive") {
            anyhow::bail!("keep-alive request {i} was not kept alive:\n{head}");
        }
        let v = json::parse(&resp).map_err(|e| anyhow::anyhow!("bad completion body: {e}"))?;
        let done = v
            .get("usage")
            .and_then(|u| u.get("completion_tokens"))
            .and_then(|c| c.as_u64())
            .unwrap_or(0);
        if done != max_tokens as u64 {
            anyhow::bail!("keep-alive request {i}: {done} of {max_tokens} tokens: {resp}");
        }
        println!(
            "  keep-alive request {} of {n}: {done} tokens on the same socket",
            i + 1
        );
    }
    println!("keep-alive socket served {n} completions without reconnecting");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    // Spawn serve-http in-process unless --addr points at a live one.
    let (spawned, addr) = match args.get("addr") {
        Some(a) => (None, a.parse::<SocketAddr>()?),
        None => {
            let cfg = ServingConfig::default_8b().with_policy(Policy::Duet);
            let server = Server::start(move || Ok(ServerCore::sim(cfg, 1).with_queue_depth(64)))?;
            let http = HttpServer::start("127.0.0.1:0", server, HttpConfig::default())?;
            let addr = http.addr();
            println!("spawned serve-http on {addr}");
            (Some(http), addr)
        }
    };

    // Keep-alive repeat mode: exercise socket reuse and return without
    // draining the target server (the caller owns its lifecycle).
    if let Some(n) = args.usize_opt("keep-alive").map_err(|e| anyhow::anyhow!(e))? {
        keep_alive_run(
            addr,
            n,
            args.f64_or("arrival-step", 0.0),
            args.usize_or("max-tokens", 6),
        )?;
        if let Some(http) = spawned {
            let rep = http.shutdown()?;
            println!("drained spawned server: {} completed", rep.completed);
        }
        return Ok(());
    }

    // 1. Streaming completion: raw socket, SSE frames as they arrive.
    let body = r#"{"prompt":"duetserve streaming demo","max_tokens":10,"stream":true}"#;
    let mut s = connect(addr)?;
    write!(
        s,
        "POST /v1/completions HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut reader = BufReader::new(s);
    let mut status = String::new();
    reader.read_line(&mut status)?;
    if !status.starts_with("HTTP/1.1 200") {
        anyhow::bail!("streaming request failed: {status}");
    }
    let mut streamed = 0u64;
    for line in reader.lines() {
        let line = line?;
        let Some(payload) = line.strip_prefix("data: ") else {
            continue;
        };
        if payload == "[DONE]" {
            break;
        }
        let chunk =
            json::parse(payload).map_err(|e| anyhow::anyhow!("bad SSE chunk `{payload}`: {e}"))?;
        let choice = chunk
            .get("choices")
            .and_then(|c| c.as_array())
            .and_then(|c| c.first())
            .ok_or_else(|| anyhow::anyhow!("chunk without choices: {payload}"))?;
        if let Some(tok) = choice.get("token_id").and_then(|t| t.as_i64()) {
            streamed += 1;
            let at = choice.get("at").and_then(|a| a.as_f64()).unwrap_or(0.0);
            println!("  token {streamed}: {tok} (engine clock {:.0} ms)", at * 1e3);
        } else if let Some(fin) = choice.get("finish_reason").and_then(|f| f.as_str()) {
            println!("  finish_reason: {fin}");
        }
    }
    if streamed != 10 {
        anyhow::bail!("expected 10 streamed tokens, got {streamed}");
    }

    // 2. Non-streaming completion.
    let (status, body) = exchange(
        addr,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt":[5,11,17,23],"max_tokens":6}"#),
    )?;
    let v = json::parse(&body).map_err(|e| anyhow::anyhow!("bad completion body: {e}"))?;
    let n_tokens = v
        .get("usage")
        .and_then(|u| u.get("completion_tokens"))
        .and_then(|c| c.as_u64())
        .unwrap_or(0);
    println!("non-streaming: status {status}, {n_tokens} completion tokens");
    if status != 200 || n_tokens != 6 {
        anyhow::bail!("unexpected non-streaming response: {body}");
    }

    // 3. Health + metrics.
    let (status, health) = exchange(addr, "GET", "/healthz", None)?;
    println!("healthz: {status} {health}");
    let (status, metrics) = exchange(addr, "GET", "/metrics", None)?;
    let tokens_line = metrics
        .lines()
        .find(|l| l.starts_with("duetserve_http_tokens_streamed_total"))
        .unwrap_or("duetserve_http_tokens_streamed_total <missing>");
    println!("metrics: {status} ({tokens_line})");
    if !metrics.contains("duetserve_engine_completed_total") {
        anyhow::bail!("metrics payload missing engine snapshot:\n{metrics}");
    }

    // 4. Graceful drain over the wire; the response is the final report.
    let (status, report) = exchange(addr, "POST", "/shutdown", None)?;
    let rep = json::parse(&report).map_err(|e| anyhow::anyhow!("bad report: {e}"))?;
    println!(
        "shutdown: {status}; completed {} requests, queue-cap {}",
        rep.get("completed").and_then(|c| c.as_u64()).unwrap_or(0),
        rep.get("queue_cap")
            .and_then(|q| q.as_u64())
            .map(|q| q.to_string())
            .unwrap_or_else(|| "n/a".into()),
    );
    if let Some(http) = spawned {
        let final_rep = http.join()?;
        println!(
            "in-process handle drained too: {} completed ({})",
            final_rep.completed, final_rep.system
        );
        if final_rep.completed != 2 {
            anyhow::bail!("expected 2 completed requests, got {}", final_rep.completed);
        }
    }
    println!("http transport round trip OK");
    Ok(())
}
