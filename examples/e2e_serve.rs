//! End-to-end driver (DESIGN.md "End-to-end validation"): load the REAL
//! tiny Qwen3-style model compiled AOT from JAX+Pallas, and serve batched
//! requests from rust through PJRT — measuring real wall-clock TTFT, TBT
//! and throughput for the prefill-first baseline vs DuetServe-style
//! decode-priority look-ahead scheduling.
//!
//! Prerequisite: `make artifacts` (python runs once, never at serving
//! time).
//!
//!     cargo run --release --example e2e_serve

use duetserve::runtime::{artifacts, RealEngine, RealPolicy, RealRequest, TinyRuntime};
use duetserve::util::tablefmt::Table;

fn requests(n: usize) -> Vec<RealRequest> {
    (0..n)
        .map(|i| RealRequest {
            id: i as u64,
            // Deterministic pseudo-prompts over the tiny vocab.
            prompt: (0..12 + (i % 20))
                .map(|j| ((i * 131 + j * 17 + 7) % 2048) as i32)
                .collect(),
            max_new_tokens: 24,
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    if !artifacts::artifacts_available() {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("loading AOT artifacts (HLO text -> PJRT CPU)...");

    let mut table = Table::new(vec![
        "policy",
        "done",
        "wall(s)",
        "req/s",
        "out-tok",
        "dec-tok/s",
        "ttft-mean(ms)",
        "ttft-p99(ms)",
        "tbt-mean(ms)",
        "tbt-p99(ms)",
    ]);

    let n = 24;
    for policy in [
        RealPolicy::PrefillFirst,
        RealPolicy::DuetInterleave { lookahead: 4 },
    ] {
        let rt = TinyRuntime::load_default()?;
        if matches!(policy, RealPolicy::PrefillFirst) {
            println!("platform: {}", rt.platform());
        }
        let mut engine = RealEngine::new(rt, policy);
        let stats = engine.serve(requests(n))?;
        assert_eq!(stats.completed, n, "all requests must complete");
        table.row(vec![
            stats.policy.to_string(),
            format!("{}", stats.completed),
            format!("{:.2}", stats.wall_s),
            format!("{:.2}", stats.throughput_rps),
            format!("{}", stats.output_tokens),
            format!("{:.1}", stats.decode_tokens_per_s),
            format!("{:.1}", stats.ttft.mean * 1e3),
            format!("{:.1}", stats.ttft.p99 * 1e3),
            format!("{:.1}", stats.tbt.mean * 1e3),
            format!("{:.1}", stats.tbt.p99 * 1e3),
        ]);
    }
    println!();
    table.print();
    println!(
        "\nAll layers composed: Pallas kernel -> JAX model -> HLO text ->\n\
         PJRT CPU executable -> rust continuous-batching coordinator.\n\
         (Weights stay device-resident across calls; the coordinator owns\n\
         the KV cache and pads decode batches to the captured graph size,\n\
         exactly like CUDA-Graph serving.)"
    );
    Ok(())
}
