//! End-to-end driver: load the REAL tiny Qwen3-style model compiled AOT
//! from JAX+Pallas and serve batched requests from rust through PJRT —
//! driven by the *same* unified serving lifecycle (`EngineCore` +
//! scheduler + `server::ServerCore`) the simulations use, with the
//! `PjrtBackend` plugged into the execution seam. Real wall-clock TTFT,
//! TBT and throughput are reported from the shared metrics structs,
//! comparing a prefill-priority baseline scheduler against the
//! decode-priority chunked scheduler.
//!
//! Prerequisite: `make artifacts` (python runs once, never at serving
//! time) and a build with `--features xla-pjrt`.
//!
//!     cargo run --release --example e2e_serve

use duetserve::config::{Policy, ServingConfig};
use duetserve::runtime::{artifacts, PjrtBackend};
use duetserve::sched::{scheduler_for, SglangDefaultScheduler};
use duetserve::server::{ServerCore, SubmitOptions};
use duetserve::util::tablefmt::Table;

fn submit_all(core: &mut ServerCore, n: usize) -> Vec<duetserve::server::RequestHandle> {
    (0..n)
        .map(|i| {
            // Deterministic pseudo-prompts over the tiny vocab.
            let prompt: Vec<i32> = (0..12 + (i % 20))
                .map(|j| ((i * 131 + j * 17 + 7) % 2048) as i32)
                .collect();
            core.submit(
                prompt,
                SubmitOptions {
                    max_new_tokens: 24,
                    ..Default::default()
                },
            )
            .expect("submit")
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    if !artifacts::artifacts_available() {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("loading AOT artifacts (HLO text -> PJRT CPU)...");

    let mut table = Table::new(vec![
        "scheduler",
        "done",
        "wall(s)",
        "req/s",
        "out-tok",
        "ttft-mean(ms)",
        "tbt-mean(ms)",
        "tbt-p99(ms)",
    ]);

    let n = 24;
    for prefill_first in [true, false] {
        let backend = PjrtBackend::load_default()?;
        if prefill_first {
            println!("platform: {}", backend.platform());
        }
        let cfg = backend.tune_config(ServingConfig::default_8b().with_policy(Policy::VllmChunked));
        // Prefill-priority baseline (SGLang-default flavoured) vs the
        // decode-priority chunked scheduler — same engine, same backend.
        let scheduler: Box<dyn duetserve::sched::Scheduler> = if prefill_first {
            Box::new(SglangDefaultScheduler::new(
                2 * cfg.token_budget as u64,
                cfg.max_batch as usize,
            ))
        } else {
            scheduler_for(&cfg)
        };
        let mut core = ServerCore::new(cfg, scheduler, Box::new(backend));
        let handles = submit_all(&mut core, n);
        core.run_to_idle();
        let mut out_tokens = 0usize;
        for h in handles {
            out_tokens += h.collect().len();
        }
        let rep = core.finish();
        assert_eq!(rep.completed, n as u64, "all requests must complete");
        table.row(vec![
            rep.system.clone(),
            format!("{}", rep.completed),
            format!("{:.2}", rep.duration),
            format!("{:.2}", rep.throughput_rps),
            format!("{out_tokens}"),
            format!("{:.1}", rep.ttft.mean * 1e3),
            format!("{:.1}", rep.tbt.mean * 1e3),
            format!("{:.1}", rep.tbt_p99 * 1e3),
        ]);
    }
    println!();
    table.print();
    println!(
        "\nAll layers composed: Pallas kernel -> JAX model -> HLO text ->\n\
         PJRT CPU executable -> the same EngineCore/server lifecycle the\n\
         simulations run, via the ExecutionBackend seam. (Weights stay\n\
         device-resident across calls; the engine owns KV accounting and\n\
         the runtime pads decode batches to the captured graph size,\n\
         exactly like CUDA-Graph serving.)"
    );
    Ok(())
}
