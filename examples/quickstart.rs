//! Quickstart: serve a synthetic workload with DuetServe and a vLLM-style
//! baseline on the simulated H100, and print the comparison.
//!
//!     cargo run --release --example quickstart

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::engine_for;
use duetserve::metrics::Report;
use duetserve::util::tablefmt::Table;
use duetserve::workload::synthetic::fixed_workload;

fn main() {
    // Qwen3-8B shapes on one simulated H100, 8192-token budget, 100 ms
    // TBT SLO — the paper's default configuration.
    let base = ServingConfig::default_8b();

    // 60 requests: 8000-token prompts, 200 output tokens, Poisson @ 6 QPS
    // (the Fig. 2 demo workload).
    let workload = fixed_workload(60, 8000, 200, 6.0, 42);

    let mut table = Table::new(Report::header());
    for policy in [Policy::VllmChunked, Policy::SglangDefault, Policy::Duet] {
        let mut engine = engine_for(base.clone().with_policy(policy), 7);
        let report = engine.run(workload.clone());
        table.row(report.row(6.0));
        if report.spatial_iterations > 0 {
            println!(
                "{}: {} of {} iterations used SM spatial multiplexing",
                report.system, report.spatial_iterations, report.iterations
            );
        }
    }
    println!();
    table.print();
    println!(
        "\nDuetServe bounds TBT under prefill pressure by splitting the GPU\n\
         (Algorithm 1) only when the roofline model predicts an SLO violation."
    );
}
