//! Inspect the Algorithm-1 partition optimizer: for a fixed decode batch
//! and growing prefill pressure, print the chosen (S_d, S_p, k), the
//! predicted side latencies, and the throughput objective ρ.
//!
//!     cargo run --release --example partition_sweep

use duetserve::config::{GpuSpec, ModelSpec};
use duetserve::model::AttnShape;
use duetserve::roofline::{BatchShape, Predictor};
use duetserve::sched::optimize_partition;
use duetserve::util::tablefmt::Table;

fn decode_batch(n: u64, ctx: u64) -> BatchShape {
    BatchShape::from_shapes((0..n).map(|_| AttnShape { q: 1, c: ctx }).collect())
}

fn main() {
    let pred = Predictor::new(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1);
    let slo = 0.100;
    println!(
        "Algorithm 1 on Qwen3-8B / H100 (66 TPCs), TBT SLO {} ms\n",
        slo * 1e3
    );

    let mut t = Table::new(vec![
        "decode", "ctx", "prefill-tok", "Sd(tpc)", "Sp(tpc)", "k", "t_d(ms)", "t_p(ms)",
        "rho(tok/s)", "span(ms)",
    ]);
    for &(n_dec, ctx) in &[(16u64, 2048u64), (32, 4096), (64, 8192), (128, 8192)] {
        for &pre_tok in &[2048u64, 4096, 8192] {
            let dec = decode_batch(n_dec, ctx);
            let pre = BatchShape::from_shapes(vec![AttnShape { q: pre_tok, c: 0 }]);
            match optimize_partition(&pred, &dec, &pre, slo, 32) {
                Some(p) => {
                    t.row(vec![
                        format!("{n_dec}"),
                        format!("{ctx}"),
                        format!("{pre_tok}"),
                        format!("{}", p.decode.n_tpcs),
                        format!("{}", p.prefill.n_tpcs),
                        format!("{}", p.k),
                        format!("{:.1}", p.t_decode * 1e3),
                        format!("{:.1}", p.t_prefill * 1e3),
                        format!("{:.0}", p.rho),
                        format!("{:.1}", p.span() * 1e3),
                    ]);
                }
                None => {
                    t.row(vec![
                        format!("{n_dec}"),
                        format!("{ctx}"),
                        format!("{pre_tok}"),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "infeasible".into(),
                        "-".into(),
                    ]);
                }
            }
        }
    }
    t.print();
    println!(
        "\nNote how the optimizer gives decode just enough TPCs to hold the\n\
         SLO and spends the rest on prefill; k bridges t_p / t_d so neither\n\
         side idles (§4.2)."
    );
}
