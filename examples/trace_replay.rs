//! Replay a Table-1-calibrated trace (Azure-Code / Azure-Conv / Mooncake)
//! across all five systems at a chosen QPS on the simulated testbed.
//!
//!     cargo run --release --example trace_replay -- [trace] [qps] [n]
//!     cargo run --release --example trace_replay -- mooncake 4 300

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{engine_for, DisaggEngine};
use duetserve::metrics::Report;
use duetserve::util::tablefmt::Table;
use duetserve::workload::traces::{generate, trace_by_name, TraceKind};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let trace = args
        .get(1)
        .and_then(|s| trace_by_name(s))
        .unwrap_or(TraceKind::AzureConv);
    let qps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8.0);
    let n: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(400);

    let workload = generate(trace, Some(n), qps, 2026);
    let stats = workload.stats();
    println!(
        "trace {}: {} requests, mean ISL {:.0}, mean OSL {:.0}, qps {qps}\n",
        workload.name, stats.n_requests, stats.mean_isl, stats.mean_osl
    );

    let base = ServingConfig::default_8b();
    let mut table = Table::new(Report::header());
    for policy in [
        Policy::VllmChunked,
        Policy::SglangDefault,
        Policy::SglangChunked,
        Policy::Duet,
    ] {
        let mut e = engine_for(base.clone().with_policy(policy), 1);
        table.row(e.run(workload.clone()).row(qps));
    }
    // Dynamo 1P+1D on two GPUs.
    let mut disagg = DisaggEngine::new(
        base.clone().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        }),
        1,
        1,
        1,
    );
    table.row(disagg.run(workload).row(qps));
    table.print();
}
